"""TuneService: the deterministic asynchronous tuning control loop.

``Study.tune(executor="async", ...)`` lands here.  The service owns the
optimizer (SMAC / random), the optional ASHA scheduler, the study journal
and a :class:`~repro.core.tune_service.executor.TrialExecutor`, and drives
them with ONE invariant: **every decision happens at canonical commit
time**.  Work units (trial evaluation segments) are created in a
deterministic order; the executor runs them on whichever slot frees first
but hands results back in creation order; asks, rung decisions and CRN
tells all fire at those commits.  Consequently the entire study — trial
table, journal, incumbent — is a pure function of ``(spec, budget, slots,
scheduler, optimizer parameters)``, independent of wall-clock completion
order, thread scheduling, or being killed and resumed.

The ask-ahead window generalizes the synchronous loop: a new trial is
asked whenever fewer than ``slots`` units are outstanding and budget
remains.  At ``slots=1`` with no scheduler this reduces *exactly* to the
sequential ask -> evaluate -> tell loop (same optimizer-RNG consumption,
same B=1 evaluations, same seeds/batch offsets), so the synchronous
path's incumbent is reproduced bit-identically — the equivalence the
acceptance tests pin for all five engines.

CRN groups: trials asked together at one window refill form a group;
their tells are buffered and committed per-group (``tell_batch(crn=)``)
once every member lands, in trial-index order — the per-CRN-group
journal-commit-time debiasing of the out-of-order ``tell_batch`` bugfix.
Singleton groups use plain ``tell`` (matching the sequential loop).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional

import numpy as np

from ..bo.smac import Observation, RandomSearch, SMACOptimizer
from ..bo.tuner import TuningResult
from ..knobs import KnobSpace, get_space
from ..simulator import run_simulation_segment
from ..workloads import make_workload
from .asha import ASHAScheduler, PROMOTE
from .executor import TrialExecutor
from .faults import NO_FAULTS, FaultPlan
from .journal import VERSION, StudyJournal
from .trial import FAILED, PAUSED, RUNNING, TERMINATED, Trial

SCHEDULERS = (None, "asha")
EXECUTORS = ("local", "fleet")

#: fleet lease lifecycle event types (journaled at unit commit time);
#: v3 adds ``reject`` (an invalid frame killed the lease) and
#: ``reconnect`` (a re-greeted worker re-attached its live lease)
HISTORY_EVENTS = ("lease", "expire", "reissue", "reject", "reconnect")


def _jsonify(obj):
    """Recursively coerce numpy scalars so configs/specs journal cleanly
    (and compare equal against their JSON round-trip on replay)."""
    if isinstance(obj, Mapping):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return [_jsonify(v) for v in obj.tolist()]
    return obj


#: per-process workload cache for process-pool slots (keyed by wl spec)
_WL_CACHE: Dict[tuple, Any] = {}


def _eval_segment(payload: Dict[str, Any]) -> Dict[str, Any]:
    """One simulator evaluation segment (module-level: process-picklable).

    Thread pools ship the prebuilt workload object; process pools ship the
    spec tuple and build/cache per worker (builds are deterministic)."""
    wl = payload.get("workload")
    if wl is None:
        key = tuple(payload["wl_spec"])
        wl = _WL_CACHE.get(key)
        if wl is None:
            wl = make_workload(key[0], key[1], threads=key[2],
                               scale=key[3], seed=key[4])
            _WL_CACHE[key] = wl
    out = run_simulation_segment(
        wl, payload["engine"], [payload["config"]],
        machine=payload["machine"],
        fast_slow_ratio=payload["fast_slow_ratio"],
        seeds=payload["seed"], sampler=payload["sampler"],
        fast_capacity_pages=payload["fast_capacity_pages"],
        backend=payload["backend"], crn=payload["crn"],
        batch_offset=payload["batch_offset"],
        exact_select=payload["exact_select"],
        epoch_start=payload["lo"], epoch_stop=payload["hi"],
        carry=payload["carry"], return_carry=payload["return_carry"])
    return {"wall_ms": out["wall_ms"][:, 0], "carry": out["carry"]}


def _eval_objective(objective: Callable[[Mapping[str, Any]], float],
                    config: Mapping[str, Any]) -> Dict[str, Any]:
    """Custom user objective evaluation (thread slots)."""
    return {"value": float(objective(config))}


@dataclasses.dataclass
class AsyncTuningResult(TuningResult):
    """A :class:`~repro.core.bo.tuner.TuningResult` plus the async service's
    receipts: the full trial table, slot utilization and ASHA savings."""

    slots: int = 1
    scheduler: Optional[str] = None
    #: trial-table rows (:meth:`Trial.to_row`), creation order
    trials: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    max_epochs: int = 0
    #: sum of trials' committed epoch budgets (semantic work; the ASHA
    #: savings receipt compares this against budget * max_epochs)
    epochs_committed: int = 0
    #: epochs actually simulated this run (numpy-path re-runs and resumed
    #: trials differ from epochs_committed)
    epochs_evaluated: int = 0
    busy_s: float = 0.0                 # summed slot occupancy
    makespan_s: float = 0.0             # submit-to-last-commit wall clock
    journal_path: Optional[str] = None
    resumed: bool = False
    #: fleet receipt (:meth:`FleetExecutor.stats`): re-issue counts,
    #: worker deaths/respawns, re-issue overhead, time-to-recover
    fleet: Optional[Dict[str, Any]] = None

    @property
    def utilization(self) -> float:
        """Mean busy fraction of the evaluation slots."""
        return self.busy_s / max(self.slots * self.makespan_s, 1e-12)

    @property
    def asha_epochs_saved_frac(self) -> float:
        """Fraction of full-budget epoch work the scheduler skipped."""
        full = self.budget * max(self.max_epochs, 1)
        return 1.0 - self.epochs_committed / max(full, 1)

    @property
    def n_failed(self) -> int:
        return sum(1 for t in self.trials if t["state"] == FAILED)

    @property
    def n_stopped_early(self) -> int:
        return sum(1 for t in self.trials
                   if t["state"] == TERMINATED
                   and t["epochs_run"] < self.max_epochs)

    @property
    def best_row(self) -> Dict[str, Any]:
        """The incumbent: best fully-evaluated trial (extrapolated values
        of ASHA-stopped trials never claim the incumbency)."""
        full = [t for t in self.trials
                if t["state"] == TERMINATED
                and t["epochs_run"] >= self.max_epochs
                and t["value"] is not None]
        if not full:
            raise ValueError("study produced no fully-evaluated trial")
        return min(full, key=lambda t: (t["value"], t["index"]))

    @property
    def best(self) -> Observation:
        row = self.best_row
        return Observation(dict(row["config"]), float(row["value"]))


class TuneService:
    """One asynchronous tuning study; see the module docstring.

    Built and run by ``Study.tune(executor="async")`` — not usually
    constructed directly.
    """

    def __init__(self, study, *, budget: int = 100, slots: int = 1,
                 scheduler: Optional[str] = None, seed: int = 0,
                 optimizer: str = "smac", n_init: int = 20,
                 random_prob: float = 0.20,
                 space: Optional[KnobSpace] = None,
                 surrogate: Optional[str] = None,
                 acquisition: Optional[str] = None,
                 objective: Optional[Callable] = None,
                 journal: Optional[str] = None, resume: bool = False,
                 pool: str = "thread", eta: int = 4,
                 window: Optional[int] = None,
                 verbose: bool = False,
                 executor: str = "local", workers: Optional[int] = None,
                 retries: int = 1, timeout_s: Optional[float] = None,
                 faults: FaultPlan = NO_FAULTS,
                 heartbeat_s: Optional[float] = None,
                 lease_deadline: Optional[int] = None,
                 max_respawns: Optional[int] = None,
                 fleet_spec=None):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; expected "
                             f"one of {SCHEDULERS}")
        if executor not in EXECUTORS:
            raise ValueError(f"unknown executor {executor!r}; expected "
                             f"one of {EXECUTORS}")
        if fleet_spec is not None and executor != "fleet":
            raise ValueError("fleet_spec= requires executor='fleet'")
        if executor == "fleet":
            from .coordinator import FLEET_POOLS
            if workers is not None:
                slots = int(workers)
            if fleet_spec is not None:
                # the spec is the deployment artifact: it fixes the pool
                # (socket), the worker count and the heartbeat/lease
                # parameters the workers were launched with
                pool = "socket"
                slots = fleet_spec.workers
                if heartbeat_s is None:
                    heartbeat_s = fleet_spec.heartbeat_s
                if lease_deadline is None:
                    lease_deadline = fleet_spec.lease_deadline
            elif pool not in FLEET_POOLS:
                pool = "process"  # fleet workers are remote by definition
        if scheduler is not None and objective is not None:
            raise ValueError(
                "scheduler='asha' needs partial-epoch objectives, which "
                "only the built-in simulator objective provides; drop "
                "objective= or use scheduler=None")
        if resume and journal is None:
            raise ValueError("resume=True requires journal=<path>")
        self.study = study
        self.spec = study.spec
        self.budget = int(budget)
        self.slots = int(slots)
        # the ask-ahead window: refills trigger whenever a slot would
        # otherwise idle (outstanding < slots) and top the window up, so a
        # window larger than slots amortizes several asks into ONE
        # ask_batch call (one surrogate fit) without ever letting a slot
        # drain.  window == slots (the default) asks exactly as the
        # synchronous loop does at slots=1.
        self.window = max(self.slots, int(window) if window is not None
                          else self.slots)
        self.scheduler_name = scheduler
        self.seed = int(seed)
        self.pool = pool
        self.verbose = verbose
        self.objective = objective
        self.executor_kind = executor
        self.retries = int(retries)
        self.timeout_s = timeout_s
        self.faults = faults if faults is not None else NO_FAULTS
        self.heartbeat_s = heartbeat_s
        self.lease_deadline = lease_deadline
        self.max_respawns = max_respawns
        self.fleet_spec = fleet_spec
        # fleet workers (and process slots) evaluate in other processes, so
        # units ship the workload spec tuple rather than the built object
        self._ship_spec = pool in ("process", "socket")
        self.crn = bool(self.spec.options.crn)
        self.space = space if space is not None \
            else get_space(self.spec.engine.name)
        if optimizer == "smac":
            self.optimizer = SMACOptimizer(
                self.space, seed=seed, n_init=n_init,
                random_prob=random_prob, surrogate=surrogate,
                acquisition=acquisition)
        elif optimizer == "random":
            self.optimizer = RandomSearch(self.space, seed=seed)
        else:
            raise ValueError(f"unknown optimizer {optimizer!r}")
        self.optimizer_name = optimizer
        self.workload = study.workload()
        self.max_epochs = int(self.workload.n_epochs)
        self.sched = ASHAScheduler(self.max_epochs, eta=eta) \
            if scheduler == "asha" else None
        self.journal_path = journal
        self.journal = StudyJournal(journal, resume=resume) \
            if journal is not None else None
        self.resumed = bool(resume)
        # header params journaled for the replay-divergence guard
        self._header = {
            "event": "study", "version": VERSION,
            "spec": _jsonify(self.spec.to_dict()),
            "budget": self.budget, "slots": self.slots,
            "window": self.window, "scheduler": scheduler,
            "rung_epochs": list(self.sched.rung_epochs) if self.sched
            else [self.max_epochs],
            "eta": self.sched.eta if self.sched else None,
            "optimizer": optimizer, "opt_seed": self.seed,
            "n_init": int(n_init), "random_prob": float(random_prob),
            "custom_objective": objective is not None,
            "executor": self.executor_kind, "retries": self.retries,
            # the lease deadline is a heartbeat COUNT (wall-clock-free);
            # None defers to the coordinator default
            "lease_deadline": self.lease_deadline,
            "timeout_s": self.timeout_s,
        }
        self._machine = study.machine
        opts = self.spec.options
        # fleet×ASHA (ROADMAP 3a closed): a rung's partial-epoch state is
        # never shipped across the lease protocol.  Instead every rung
        # unit re-derives its prefix by evaluating [0, hi) from scratch —
        # exact on both backends (`run_simulation_segment` is pinned
        # segmented == unsegmented bitwise), it keeps each work unit a
        # pure function of (config, hi) so straggler re-issue and
        # first-commit-wins compose with promotion unchanged, and it
        # keeps result frames small and cap-friendly (a scan carry holds
        # per-page arrays).  So: no checkpoint carries under the fleet.
        self._can_checkpoint = objective is None and \
            opts.backend == "jax" and executor != "fleet" and \
            self._jax_supported()
        # bookkeeping
        self._units: Dict[int, Dict[str, Any]] = {}
        self._trials: List[Trial] = []
        self._groups: Dict[int, Dict[str, Any]] = {}
        self._next_group = 0
        self._asked = 0
        self._default_value: Optional[float] = None
        self._epochs_evaluated = 0
        self.executor: Optional[TrialExecutor] = None

    def _jax_supported(self) -> bool:
        from .. import engine_jax
        return engine_jax.supports(self.spec.engine.name,
                                   self.spec.options.sampler,
                                   self.workload.n_pages)

    # -- unit construction -------------------------------------------------
    def _segment_payload(self, config, lo: int, hi: int, carry
                         ) -> Dict[str, Any]:
        opts = self.spec.options
        wl = self.workload
        p = {
            "engine": self.spec.engine.name, "config": dict(config),
            "machine": self._machine,
            "fast_slow_ratio": self.spec.fast_slow_ratio,
            "seed": opts.seed, "sampler": opts.sampler,
            "fast_capacity_pages": self.spec.fast_capacity_pages,
            "backend": opts.backend, "crn": opts.crn,
            "batch_offset": 0, "exact_select": opts.exact_select,
            "lo": lo, "hi": hi, "carry": carry,
            "return_carry": self._can_checkpoint,
        }
        if self._ship_spec:
            p["wl_spec"] = (wl.name, wl.input_name, wl.threads, wl.scale,
                            wl.seed)
        else:
            p["workload"] = wl
        return p

    def _submit_unit(self, unit: Dict[str, Any]) -> None:
        """Enqueue one work unit, consulting the journal's replay cache:
        cache hits hold their canonical commit slot without occupying an
        evaluation slot."""
        ex = self.executor
        t: Optional[Trial] = unit.get("trial")
        if self.journal is not None and self.journal.replaying:
            if t is None:
                hit = self.journal.lookup("default")
                if hit is not None:
                    unit["cached"] = True
                    unit["seq"] = ex.submit_ready(
                        {"cached_value": hit["value"]})
                    self._units[unit["seq"]] = unit
                    return
            else:
                # the FIRST unconsumed event at (trial, epochs) decides the
                # unit's replayed fate: a ``retry`` precedes the eventual
                # ``eval``/``fail`` at the same epochs, so an errored
                # attempt replays its error (and re-journals the retry at
                # commit) before the resubmitted twin finds the final value
                hit = self.journal.lookup_first(
                    ("retry", "eval", "fail"), trial=t.index,
                    epochs=unit["hi"])
                if hit is not None:
                    unit["cached"] = True
                    if hit["event"] == "eval":
                        unit["seq"] = ex.submit_ready(
                            {"cached_value": hit["value"]})
                    else:
                        unit["seq"] = ex.submit_ready(
                            {"error": hit["error"]})
                    self._units[unit["seq"]] = unit
                    return
        config = self.space.default_config() if t is None else t.config
        if self.objective is not None:
            seq = ex.submit(_eval_objective, self.objective, config)
        else:
            lo, hi = unit["lo"], unit["hi"]
            carry = None
            if t is not None and self._can_checkpoint and \
                    t.checkpoint is not None and t.epochs_run == lo:
                carry = t.checkpoint
            if carry is None and lo != 0:
                # no usable checkpoint (numpy path, or a resumed trial
                # whose earlier rungs were cache hits): re-run the prefix
                unit["lo"] = lo = 0
            seq = ex.submit(_eval_segment,
                            self._segment_payload(config, lo, hi, carry))
        unit["seq"] = seq
        self._units[seq] = unit

    def _start_trial_unit(self, t: Trial, hi: int) -> None:
        t.advance(RUNNING)
        self._submit_unit({"trial": t, "rung": t.rung,
                           "lo": t.epochs_run, "hi": hi})

    def _rung_budget(self, rung: int) -> int:
        return self.sched.rung_epochs[rung] if self.sched else self.max_epochs

    # -- the ask-ahead window ---------------------------------------------
    def _refill(self) -> None:
        if self.executor.outstanding >= self.slots:
            return  # every slot is busy; don't ask on stale information
        m = min(self.window - self.executor.outstanding,
                self.budget - self._asked)
        if m <= 0:
            return
        cfgs = self.optimizer.ask_batch(m)
        gid = self._next_group
        self._next_group += 1
        members: List[Trial] = []
        for cfg in cfgs:
            cfg = _jsonify(cfg)
            t = Trial(index=self._asked, config=dict(cfg),
                      encoded=self.space.encode(cfg),
                      spec=self._header["spec"],
                      seed=int(self.spec.options.seed), batch_offset=0,
                      group=gid)
            self._asked += 1
            self._trials.append(t)
            members.append(t)
            self._journal({"event": "ask", "trial": t.index, "group": gid,
                           "config": t.config})
        self._groups[gid] = {"members": members, "done": 0}
        for t in members:
            self._start_trial_unit(t, self._rung_budget(0))

    # -- commits -----------------------------------------------------------
    def _journal(self, event: Dict[str, Any]) -> Dict[str, Any]:
        if self.journal is None:
            return event
        return self.journal.append(event)

    def _journal_history(self, seq: int, unit: Dict[str, Any]) -> None:
        """Journal the unit's fleet lease history (lease/expire/reissue) at
        its commit point — the only place those events are deterministic.
        Live units re-generated their histories and append strictly; a
        replay cache hit never re-executed, so its recorded history is
        adopted verbatim."""
        if unit.get("cached"):
            if self.journal is not None:
                self.journal.consume_history(HISTORY_EVENTS, unit=seq)
            return
        for ev in self.executor.take_history(seq):
            self._journal(ev)

    def _commit(self, seq: int, result: Dict[str, Any]) -> None:
        unit = self._units.pop(seq)
        self._journal_history(seq, unit)
        t: Optional[Trial] = unit.get("trial")
        if t is None:  # the default-config baseline
            if "error" in result:
                raise RuntimeError(
                    "default-config baseline evaluation failed:\n"
                    + result["error"])
            v = result["cached_value"] if "cached_value" in result \
                else self._result_value(None, unit, result)
            ev = self._journal({"event": "default", "value": v})
            self._default_value = float(ev.get("value", v))
            self._refill()
            return
        t.wall_s += float(result.get("slot_s", 0.0))
        if "error" in result:
            if t.attempt < self.retries:
                # bounded retry: one transient fault must not discard the
                # trial's budget.  The retry is a journaled, deterministic
                # event — replay reproduces it — and the trial stays
                # RUNNING while its segment is resubmitted.
                t.attempt += 1
                self._journal({"event": "retry", "trial": t.index,
                               "attempt": t.attempt, "epochs": unit["hi"],
                               "error": result["error"]})
                self._submit_unit({"trial": t, "rung": t.rung,
                                   "lo": t.epochs_run, "hi": unit["hi"]})
                self._refill()
                return
            t.advance(FAILED)
            t.error = result["error"]
            t.epochs_run = unit["hi"]
            self._journal({"event": "fail", "trial": t.index,
                           "epochs": unit["hi"], "error": t.error})
            self._group_member_done(t, tell=False)
            self._refill()
            return
        if "cached_value" in result:
            value = float(result["cached_value"])
        else:
            value = self._result_value(t, unit, result)
        t.epochs_run = unit["hi"]
        t.value = value
        ev = self._journal({"event": "eval", "trial": t.index,
                            "epochs": t.epochs_run, "value": value})
        value = t.value = float(ev.get("value", value))
        if self.sched is not None and not self.sched.is_final(t.rung):
            decision = self.sched.report(t.rung, t.index, value)
            self._journal({"event": "rung", "trial": t.index,
                           "rung": t.rung, "decision": decision})
            if decision == PROMOTE:
                t.advance(PAUSED)
                t.rung += 1
                self._start_trial_unit(t, self._rung_budget(t.rung))
            else:
                # extrapolate the partial value to full budget before the
                # tell: a trial stopped at 1/4 budget must not enter the
                # surrogate as a 4x-faster config
                t.advance(TERMINATED)
                t.told_value = value * (self.max_epochs / t.epochs_run)
                self._group_member_done(t, tell=True)
        else:
            t.advance(TERMINATED)
            t.told_value = value
            self._group_member_done(t, tell=True)
        self._refill()

    def _result_value(self, t: Optional[Trial], unit: Dict[str, Any],
                      result: Dict[str, Any]) -> float:
        """Fold a fresh evaluation into the trial and compute its committed
        value canonically (independent of segmentation)."""
        if "value" in result:  # custom objective
            return float(result["value"])
        wall = np.asarray(result["wall_ms"], dtype=np.float64)
        self._epochs_evaluated += len(wall)
        if t is None:
            return float(wall.sum() / 1e3)
        if unit["lo"] == 0:
            t.epoch_wall_ms = [wall]
        else:
            t.epoch_wall_ms.append(wall)
        t.checkpoint = result.get("carry")
        return t.value_at(unit["hi"])

    # -- CRN-group tells ---------------------------------------------------
    def _group_member_done(self, t: Trial, tell: bool) -> None:
        """Buffer a finished group member; once the whole CRN group has
        landed, commit its tells in trial-index order (the per-group,
        commit-time debias of the tell_batch(crn=True) fix).  FAILED
        members are excluded from the tell but still complete the group."""
        g = self._groups[t.group]
        g["done"] += 1
        if g["done"] < len(g["members"]):
            return
        live = [m for m in g["members"] if m.state == TERMINATED]
        if live:
            if len(g["members"]) == 1:
                m = live[0]
                self.optimizer.tell(m.config, m.told_value)
            else:
                self.optimizer.tell_batch(
                    [m.config for m in live],
                    [m.told_value for m in live], crn=self.crn)
            for m in live:
                self._journal({"event": "tell", "trial": m.index,
                               "group": t.group, "value": m.told_value})
                if self.verbose:
                    best = min(o.value for o in
                               self.optimizer.observations)
                    print(f"  trial {m.index + 1:4d}/{self.budget}: "
                          f"f={m.told_value:9.2f}s best={best:9.2f}s",
                          flush=True)
        del self._groups[t.group]

    # -- the run loop ------------------------------------------------------
    def run(self) -> AsyncTuningResult:
        t0 = time.time()
        self._journal(self._header)
        if self.executor_kind == "fleet":
            from .coordinator import FleetExecutor
            kw: Dict[str, Any] = {"timeout_s": self.timeout_s,
                                  "faults": self.faults}
            if self.heartbeat_s is not None:
                kw["heartbeat_s"] = self.heartbeat_s
            if self.lease_deadline is not None:
                kw["lease_deadline"] = self.lease_deadline
            if self.max_respawns is not None:
                kw["max_respawns"] = self.max_respawns
            if self.fleet_spec is not None:
                kw["fleet_spec"] = self.fleet_spec  # never journaled: the
                # spec carries the fleet's shared auth key
            self.executor = FleetExecutor(self.slots, pool=self.pool, **kw)
        else:
            self.executor = TrialExecutor(self.slots, self.pool,
                                          timeout_s=self.timeout_s)
        try:
            mk0 = time.perf_counter()
            # the default-config baseline evaluates first, exactly like the
            # synchronous loop's default_value (full budget, never told)
            self._submit_unit({"trial": None, "lo": 0,
                               "hi": self.max_epochs})
            self._refill()
            while self.executor.outstanding > 0:
                seq, result = self.executor.pop_next()
                self._commit(seq, result)
            makespan = time.perf_counter() - mk0
            rows = [t.to_row() for t in self._trials]
            result = AsyncTuningResult(
                engine=self.spec.engine.name, scenario=self.study.key,
                budget=self.budget,
                history=list(self.optimizer.observations),
                default_value=float(self._default_value),
                wall_s=time.time() - t0, round_times=[],
                slots=self.slots, scheduler=self.scheduler_name,
                trials=rows, max_epochs=self.max_epochs,
                epochs_committed=sum(r["epochs_run"] for r in rows
                                     if r["state"] == TERMINATED),
                epochs_evaluated=self._epochs_evaluated,
                busy_s=self.executor.busy_s, makespan_s=makespan,
                journal_path=self.journal_path, resumed=self.resumed,
                fleet=self.executor.stats()
                if self.executor_kind == "fleet" else None)
            best = result.best_row
            self._journal({
                "event": "done", "best_trial": best["index"],
                "best_value": best["value"],
                "n_failed": result.n_failed,
                "n_stopped_early": result.n_stopped_early})
            return result
        finally:
            self.executor.close()
            if self.journal is not None:
                self.journal.close()
