"""Typed experiment specs: the JSON-serializable contract of the Study API.

The paper's premise is evaluating *many* (engine, workload, machine,
knob-config) combinations through one objective.  Historically each entry
point re-spelled that combination as loose strings and scattered kwargs;
these frozen dataclasses put every axis in ONE typed, validated place:

* :class:`EngineSpec` — engine name (registry-validated) + knob config
  (validated/completed against the engine's :class:`~repro.core.knobs.
  KnobSpace` when one is registered);
* :class:`WorkloadSpec` — workload name (registry-validated) + input,
  thread count and simulation scale;
* :class:`SimOptions` — *how* to evaluate: seed, sampler, workers, backend
  and heatmap recording, in one place instead of four call signatures;
* :class:`ExperimentSpec` — the composition, plus machine name and
  fast:slow ratio.

All four round-trip through plain JSON dicts (``to_dict``/``from_dict``), so
results saved under ``benchmarks/results/`` embed replayable specs::

    spec = ExperimentSpec.from_dict(json.load(f)["spec"])
    Study(spec).run()
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Union

# importing these modules registers the builtin engines, workloads, samplers,
# backends and machines the validators below resolve against
from . import engine as _engine_mod      # noqa: F401
from . import simulator as _sim_mod      # noqa: F401
from . import workloads as _workloads_mod  # noqa: F401
from .knobs import SPACES
from .registry import BACKENDS, ENGINES, MACHINES, SAMPLERS, WORKLOADS


def _freeze(obj, field: str, value) -> None:
    object.__setattr__(obj, field, value)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """A tiering engine plus a fully validated knob configuration.

    ``config=None`` resolves to the engine's default config (empty for
    engines without a registered knob space); a partial config is completed
    with defaults and clipped into the knob domain.
    """

    name: str
    config: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        ENGINES.get(self.name)  # raises with did-you-mean on unknown names
        space = SPACES.get(self.name)
        if space is None:
            cfg = dict(self.config or {})
        elif self.config is None:
            cfg = space.default_config()
        else:
            cfg = space.validate(self.config)
        _freeze(self, "config", cfg)

    def __hash__(self):
        # the dataclass-generated hash would crash on the config dict;
        # hashability lets frozen specs serve as cache/dict keys
        return hash((self.name, tuple(sorted(self.config.items()))))

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "config": dict(self.config)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "EngineSpec":
        return cls(name=d["name"], config=d.get("config"))

    @classmethod
    def coerce(cls, value: "EngineSpec | str | Mapping[str, Any]") -> "EngineSpec":
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(value)
        return cls.from_dict(value)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A workload build request: name × input × threads × simulation scale.

    ``threads=None`` defers to the machine profile's default thread count
    (resolved by :class:`~repro.core.study.Study`).
    """

    name: str
    input_name: str = ""
    threads: Optional[int] = None
    scale: float = 0.25

    def __post_init__(self):
        WORKLOADS.get(self.name)
        if not (0.0 < self.scale <= 1.0):
            raise ValueError(f"scale must be in (0, 1], got {self.scale}")

    @property
    def key(self) -> str:
        inp = f":{self.input_name}" if self.input_name else ""
        return f"{self.name}{inp}"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "WorkloadSpec":
        return cls(**dict(d))

    @classmethod
    def coerce(cls, value: "WorkloadSpec | str | Mapping[str, Any]") -> "WorkloadSpec":
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(value)
        # a DriftSpec (phase-shifting trace) coerces by registering its
        # composed workload: Study(ExperimentSpec(workload=DriftSpec(...)))
        # just works.  Lazy import — drift.py imports this module.
        from .drift import DriftSpec
        if isinstance(value, DriftSpec):
            return cls(value.register())
        return cls.from_dict(value)


@dataclasses.dataclass(frozen=True)
class SimOptions:
    """How to evaluate: every evaluation-mode option in ONE place.

    Replaces the sampler/workers/backend/seed kwargs that were previously
    scattered across four signatures (``evaluate``, ``evaluate_batch``,
    ``run_simulation``, ``tune_scenario``).  ``workers`` accepts an int or
    ``"auto"`` (process pool sized to the CPU count).

    ``crn=True`` (common random numbers) makes every config of a batch see
    bitwise-identical monitoring noise, so within-batch comparisons —
    SMAC's ``ask_batch`` candidates in particular — are paired rather than
    independently noisy.  CRN requires ``backend="jax"`` (the compiled
    epoch loop draws counter-based randomness that can be shared across
    the batch; the numpy reference engines consume sequential RNG streams
    that cannot).  Use it for *tuning/comparison* runs; leave it off when
    estimating absolute performance from independent replicas.

    ``exact_select=True`` (default) plans migrations on the jax backend
    with the exact top-k selection kernel
    (:mod:`repro.kernels.select_topk`): selected page sets are
    bit-identical to the numpy reference's stable sorts.  ``False``
    restores the historical 8-bit log-quantized selection (exact counts,
    near-exact order) for ablations.  The numpy backend is always exact;
    the flag is a no-op there.
    """

    seed: int = 0
    sampler: str = "elementwise"
    workers: Union[int, str] = 1
    backend: str = "numpy"
    crn: bool = False
    exact_select: bool = True
    record_heatmap: bool = False
    heat_bins: int = 128

    def __post_init__(self):
        SAMPLERS.get(self.sampler)
        BACKENDS.get(self.backend)
        if self.workers not in ("auto", None) and int(self.workers) < 0:
            raise ValueError(f"workers must be >= 0, got {self.workers!r}")
        if self.crn and self.backend != "jax":
            raise ValueError(
                "crn=True (common random numbers) requires backend='jax'; "
                "the numpy engines consume sequential RNG streams that "
                "cannot be shared across a batch")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SimOptions":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One fully-specified experiment: engine × workload × machine × options.

    ``engine``/``workload`` accept bare name strings as a shorthand and are
    coerced to their typed specs; ``machine`` is a registered machine name.
    """

    engine: Union[EngineSpec, str]
    workload: Union[WorkloadSpec, str]
    machine: str = "pmem-large"
    fast_slow_ratio: float = 8.0
    fast_capacity_pages: Optional[int] = None
    options: SimOptions = dataclasses.field(default_factory=SimOptions)

    def __post_init__(self):
        _freeze(self, "engine", EngineSpec.coerce(self.engine))
        _freeze(self, "workload", WorkloadSpec.coerce(self.workload))
        MACHINES.get(self.machine)
        if isinstance(self.options, Mapping):
            _freeze(self, "options", SimOptions.from_dict(self.options))

    @property
    def key(self) -> str:
        return f"{self.engine.name}/{self.workload.key}@{self.machine}"

    def to_dict(self) -> Dict[str, Any]:
        return {
            "engine": self.engine.to_dict(),
            "workload": self.workload.to_dict(),
            "machine": self.machine,
            "fast_slow_ratio": self.fast_slow_ratio,
            "fast_capacity_pages": self.fast_capacity_pages,
            "options": self.options.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        return cls(
            engine=EngineSpec.from_dict(d["engine"]),
            workload=WorkloadSpec.from_dict(d["workload"]),
            machine=d.get("machine", "pmem-large"),
            fast_slow_ratio=d.get("fast_slow_ratio", 8.0),
            fast_capacity_pages=d.get("fast_capacity_pages"),
            options=SimOptions.from_dict(d.get("options", {})),
        )
