"""Compiled tiered-KV serving: the fused decode + engine step.

This module is the jitted backend behind ``TieredKVCache(compiled=True)``.
One decode step — token append, paged attention over the HBM-resident
pages, and attention-mass read recording — is a single jitted function over
``(B, pages)`` arrays; engine epochs run as two more jitted calls (decide +
apply) with page moves batched through ONE :func:`~repro.kernels.ops.
page_migrate` call per direction instead of the per-page Python loops of
the reference path.

Conformance is **by construction**, not by tolerance:

* The engine's observe/plan math (:class:`~repro.core.engine_jax.
  KVHeMemDef`, the first lifted engine) is compiled ONCE per cache
  geometry, and the *same jitted executable* serves both the compiled path
  and the Python reference loop in :mod:`~repro.core.tiered_kv`.  XLA is
  free to fuse differently across different jit programs (observed ~1-ULP
  drift in the cooling EWMAs between eager and jitted traces), so sharing
  the executable is the only way residency decisions stay bit-identical.
* Access accounting is *integer*: one decode step charges each logical
  page ``step_read_counts`` accesses — pure int32 arithmetic, so numpy,
  eager jnp and any jit fusion produce the same bits, and the int->f32
  conversion fed to the engine is the same correctly-rounded value on both
  paths.

Structural state (``slot_of``, ``page_of_slot``, ``lengths``) is integer
throughout; both page pools carry one extra **dump row** (index ``H`` for
HBM, ``n`` for host) so every scatter/migrate index is always valid —
masked-out lanes write garbage to the dump row instead of relying on ``-1``
sentinel handling inside the kernels.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

from . import engine_jax
from .engine_jax import KVHeMemDef
from .traffic import step_read_counts  # noqa: F401  (re-export; shared
#                                        with the Python reference loop)

# this module is jax-only; bind engine_jax's lazy jax globals up front so
# the engine defs are usable without a prior simulator call
engine_jax.have_jax()


def read_scale(spec) -> int:
    """Attention-mass -> access-count scale (PEBS-knob units): one unit of
    mass is worth page_tokens x kv_heads x n_layers x 64 accesses."""
    return int(spec.page_tokens * spec.kv_heads * spec.n_layers * 64)


class CompiledServing:
    """Jitted serving functions for one cache geometry.

    All methods are pure: state pytree in, state pytree out.  Instances are
    cached per ``(spec, batch, max_pages, hbm_pages, kernel path)`` by
    :func:`get_serving` so every ``TieredKVCache`` of the same geometry —
    including the Python-loop reference, which borrows :attr:`engine_decide`
    — shares one set of compiled executables.
    """

    def __init__(self, spec, batch: int, max_pages: int, hbm_pages: int):
        self.spec = spec
        self.B, self.mp, self.H = batch, max_pages, hbm_pages
        self.n = batch * max_pages
        self.pt = spec.page_tokens
        self.scale = read_scale(spec)
        self.page_shape = (spec.n_layers, spec.page_tokens, spec.kv_heads,
                           spec.head_dim)
        self.page_elems = int(np.prod(self.page_shape))
        self.edef = KVHeMemDef(1, self.n, hbm_pages, "elementwise",
                               kops.select_path())
        self.edef.page_bytes = np.float32(self.page_elems * 2)

        # the state pytree is donated: XLA aliases the KV pools in place
        # instead of copying ~page_elems * (n + H) bytes per decode step.
        # Callers always replace their state with the returned one, so the
        # consumed buffers are never observed again.
        self._append_fn = jax.jit(self._append, donate_argnums=0)
        self._attend_fn = jax.jit(self._attend_record, donate_argnums=0)
        self._decode_fn = jax.jit(self._decode, donate_argnums=0)
        self._apply_fn = jax.jit(self._apply, donate_argnums=0)
        self._reset_fn = jax.jit(self._reset, donate_argnums=0)
        # the ONE engine-decision executable both paths share (see module
        # docstring); knob vectors are traced, so tuner configs never retrace
        self.engine_decide = jax.jit(self._engine_decide)

    # -- state -------------------------------------------------------------
    def fresh_state(self) -> Dict[str, Any]:
        B, n, H, dt = self.B, self.n, self.H, self.spec.dtype
        ps = self.page_shape
        st = {
            "lengths": jnp.zeros(B, jnp.int32),
            "slot_of": jnp.full(n + 1, -1, jnp.int32),
            "page_of_slot": jnp.full(H + 1, -1, jnp.int32),
            "allocated": jnp.zeros(n, bool),
            "reads": jnp.zeros(n, jnp.int32),
            "writes": jnp.zeros(n, jnp.int32),
            "hbm_k": jnp.zeros((H + 1,) + ps, dt),
            "hbm_v": jnp.zeros((H + 1,) + ps, dt),
            "host_k": jnp.zeros((n + 1,) + ps, dt),
            "host_v": jnp.zeros((n + 1,) + ps, dt),
            "eng": self.edef.init(None),
            "migrations": jnp.int32(0),
            "epoch": jnp.int32(0),
            "recall_num": jnp.float32(0.0),
            "recall_den": jnp.float32(0.0),
        }
        # jax dedupes identical constants (e.g. the two zero pools) into one
        # buffer; donated pytrees must not contain the same buffer twice, so
        # force every leaf onto its own storage.
        return jax.tree_util.tree_map(lambda a: jnp.array(a, copy=True), st)

    # -- decode-step pieces (traced) ---------------------------------------
    def _append(self, st, k_new, v_new, active):
        B, mp, n, H, pt = self.B, self.mp, self.n, self.H, self.pt
        t = st["lengths"]
        pi, off = t // pt, t % pt
        pid = jnp.arange(B, dtype=jnp.int32) * mp + pi       # (B,) unique
        allocated = st["allocated"].at[pid].set(
            st["allocated"][pid] | active)
        writes = st["writes"].at[pid].add(active.astype(jnp.int32))
        slot = st["slot_of"][pid]
        # first touch of a page grabs the lowest free HBM slot; the j-th
        # needy sequence (ascending b) gets the j-th lowest free slot —
        # exactly the reference loop's repeated flatnonzero(free)[0]
        need = active & (slot < 0) & (off == 0)
        free = st["page_of_slot"][:H] < 0
        n_free = free.sum()
        free_slots = jnp.sort(
            jnp.where(free, jnp.arange(H, dtype=jnp.int32), H))
        rank = jnp.cumsum(need.astype(jnp.int32))            # inclusive
        got = need & (rank <= n_free)
        new_slot = free_slots[jnp.clip(rank - 1, 0, H - 1)]
        slot = jnp.where(got, new_slot, slot)
        slot_of = st["slot_of"].at[jnp.where(got, pid, n)].set(
            jnp.where(got, new_slot, -1))
        pos = st["page_of_slot"].at[jnp.where(got, new_slot, H)].set(
            jnp.where(got, pid, -1))
        # token writes: resident rows to their slot, everything else to the
        # dump row of the respective pool
        kt = k_new.astype(self.spec.dtype)
        vt = v_new.astype(self.spec.dtype)
        rows_hbm = jnp.where(active & (slot >= 0), slot, H)
        rows_host = jnp.where(active & (slot < 0), pid, n)
        return dict(
            st, lengths=t + active.astype(jnp.int32), slot_of=slot_of,
            page_of_slot=pos, allocated=allocated, writes=writes,
            hbm_k=st["hbm_k"].at[rows_hbm, :, off].set(kt),
            hbm_v=st["hbm_v"].at[rows_hbm, :, off].set(vt),
            host_k=st["host_k"].at[rows_host, :, off].set(kt),
            host_v=st["host_v"].at[rows_host, :, off].set(vt))

    def _attend_record(self, st, q, active):
        B, mp, n = self.B, self.mp, self.n
        tbl = st["slot_of"][:n].reshape(B, mp)
        out = kops.paged_attention(
            q.astype(self.spec.dtype), st["hbm_k"][:, 0], st["hbm_v"][:, 0],
            tbl, st["lengths"])
        counts, act_page = step_read_counts(st["lengths"], mp, self.pt,
                                            self.scale, xp=jnp)
        counts = jnp.where(active[:, None], counts, 0)
        act_page = act_page & active[:, None]
        flat = counts.reshape(n)
        resident = st["slot_of"][:n] >= 0
        mass = flat.astype(jnp.float32) / np.float32(self.scale)
        st = dict(
            st, reads=st["reads"] + flat,
            recall_num=st["recall_num"]
            + jnp.sum(jnp.where(resident, mass, 0.0)),
            recall_den=st["recall_den"] + jnp.sum(mass))
        res_pages = (resident.reshape(B, mp) & act_page).sum(1)
        tot_pages = act_page.sum(1)
        return st, out, res_pages, tot_pages

    def _decode(self, st, k_new, v_new, q, active):
        st = self._append(st, k_new, v_new, active)
        return self._attend_record(st, q, active)

    # -- engine epoch (traced) ---------------------------------------------
    def _engine_decide(self, eng, kv, reads_f, writes_f, in_fast, allocated,
                       dt_ms, e):
        keys = jnp.zeros((1,), jnp.uint32)   # kv-hemem draws no noise
        est = jnp.full((1,), dt_ms, jnp.float32)
        eng, _ = self.edef.observe(eng, kv, keys, e, reads_f, writes_f, est)
        eng, pm, dm, _ = self.edef.plan(
            eng, kv, keys, e, reads_f, writes_f, in_fast[None, :],
            allocated[None, :], est, jnp.int32(self.H))
        return eng, pm[0], dm[0]

    def _mig(self, dst, src, dst_rows, src_rows):
        r = kops.page_migrate(dst.reshape(dst.shape[0], -1),
                              src.reshape(src.shape[0], -1),
                              dst_rows, src_rows)
        return r.reshape(dst.shape)

    def _apply(self, st, pmask, dmask):
        """Apply one epoch's migration masks: batched demote (HBM->host),
        then batched promote into the freed slots — promote page-ids
        ascending paired with free slots ascending, the reference loop's
        repeated lowest-free-slot rule."""
        n, H = self.n, self.H
        arn = jnp.arange(n, dtype=jnp.int32)
        slots = st["slot_of"][:n]
        dm = dmask & (slots >= 0)
        d_ids = jnp.sort(jnp.where(dm, arn, n))[:H]
        d_valid = d_ids < n
        d_rows = jnp.where(d_valid, d_ids, n)                # host dump row
        d_slots = jnp.where(d_valid, slots[jnp.minimum(d_ids, n - 1)], H)
        host_k = self._mig(st["host_k"], st["hbm_k"], d_rows, d_slots)
        host_v = self._mig(st["host_v"], st["hbm_v"], d_rows, d_slots)
        slots = jnp.where(dm, -1, slots)
        posn = st["page_of_slot"][:H]
        owner = jnp.maximum(posn, 0)
        posn = jnp.where((posn >= 0) & dm[owner], -1, posn)

        pm = pmask & (slots < 0) & st["allocated"]
        p_ids = jnp.sort(jnp.where(pm, arn, n))[:H]
        f_slots = jnp.sort(
            jnp.where(posn < 0, jnp.arange(H, dtype=jnp.int32), H))
        valid = (p_ids < n) & (f_slots < H)
        p_rows = jnp.where(valid, p_ids, n)
        p_slots = jnp.where(valid, f_slots, H)
        hbm_k = self._mig(st["hbm_k"], host_k, p_slots, p_rows)
        hbm_v = self._mig(st["hbm_v"], host_v, p_slots, p_rows)
        slot_of = jnp.concatenate([slots, st["slot_of"][n:]])
        slot_of = slot_of.at[p_rows].set(jnp.where(valid, p_slots, -1))
        pos = jnp.concatenate([posn, st["page_of_slot"][H:]])
        pos = pos.at[p_slots].set(jnp.where(valid, p_ids, -1))
        moved = dm.sum() + valid.sum()
        return dict(st, slot_of=slot_of, page_of_slot=pos,
                    hbm_k=hbm_k, hbm_v=hbm_v, host_k=host_k, host_v=host_v,
                    reads=jnp.zeros_like(st["reads"]),
                    writes=jnp.zeros_like(st["writes"]),
                    migrations=st["migrations"] + moved.astype(jnp.int32),
                    epoch=st["epoch"] + 1), moved

    def engine_step(self, st, kv, dt_ms):
        """One engine epoch on compiled state: shared decide + batched
        apply.  Returns ``(state, moved)``."""
        in_fast = st["slot_of"][:self.n] >= 0
        eng, pmask, dmask = self.engine_decide(
            st["eng"], kv, st["reads"].astype(jnp.float32),
            st["writes"].astype(jnp.float32), in_fast, st["allocated"],
            np.float32(dt_ms), st["epoch"])
        st, moved = self._apply_fn(dict(st, eng=eng), pmask, dmask)
        # the zeroed read/write accumulators are identical values, which XLA
        # may CSE into one output buffer — split them so the next donated
        # call doesn't see the same buffer twice (cheap: 2 x n int32,
        # engine epochs only)
        st = dict(st, reads=st["reads"].copy(), writes=st["writes"].copy())
        return st, int(moved)

    # -- sequence completion ----------------------------------------------
    def _reset(self, st, done):
        """Retire finished sequences: zero their lengths and access
        counters, free their HBM slots and engine heat.  Pool rows keep
        stale data; the next occupant's appends overwrite them."""
        n, H, mp = self.n, self.H, self.mp
        owner = jnp.arange(n, dtype=jnp.int32) // mp
        kill = done[owner]
        slots = st["slot_of"][:n]
        fs = kill & (slots >= 0)
        pos = st["page_of_slot"].at[jnp.where(fs, slots, H)].set(-1)
        eng = dict(st["eng"],
                   rc=jnp.where(kill[None, :], 0.0, st["eng"]["rc"]),
                   wc=jnp.where(kill[None, :], 0.0, st["eng"]["wc"]))
        return dict(
            st, lengths=jnp.where(done, 0, st["lengths"]),
            slot_of=jnp.concatenate([jnp.where(kill, -1, slots),
                                     st["slot_of"][n:]]),
            page_of_slot=pos, allocated=st["allocated"] & ~kill,
            reads=jnp.where(kill, 0, st["reads"]),
            writes=jnp.where(kill, 0, st["writes"]), eng=eng)

    # -- public jitted entry points ---------------------------------------
    def append(self, st, k_new, v_new, active):
        return self._append_fn(st, k_new, v_new, active)

    def attend(self, st, q, active):
        return self._attend_fn(st, q, active)

    def decode(self, st, k_new, v_new, q, active):
        """The fused serving step: append + paged attention + read/recall
        recording in ONE jitted call.  Returns
        ``(state, out, res_pages, tot_pages)``."""
        return self._decode_fn(st, k_new, v_new, q, active)

    def reset_seqs(self, st, done):
        return self._reset_fn(st, done)


_CACHE: Dict[Tuple, CompiledServing] = {}


def get_serving(spec, batch: int, max_pages: int,
                hbm_pages: int) -> CompiledServing:
    """Cached :class:`CompiledServing` per geometry + kernel path (the
    dispatch choice is folded in at trace time, so flipping
    ``kops.FORCE`` builds fresh executables instead of silently reusing
    ones compiled for the other path)."""
    key = (spec, batch, max_pages, hbm_pages, kops.select_path())
    srv = _CACHE.get(key)
    if srv is None:
        srv = _CACHE[key] = CompiledServing(spec, batch, max_pages,
                                            hbm_pages)
    return srv
