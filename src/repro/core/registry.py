"""Component registries: the extension seam of the typed experiment API.

Engines, workloads, samplers, simulation backends and machine profiles all
register themselves here by name; every dispatch site (``make_batch_engine``,
``make_workload``, the simulator's backend/machine lookup) resolves through a
:class:`Registry` instead of a hardcoded ``dict``/``if-elif`` chain.  Unknown
names raise ``KeyError`` with a did-you-mean suggestion and the full list of
registered names.  Registering a new component never requires touching core
dispatch code:

    from repro.core.registry import register_engine

    @register_engine("my-policy", space=MY_KNOB_SPACE)
    class BatchMyPolicyEngine(BatchTieringEngine):
        ...

    Study(ExperimentSpec(engine="my-policy", workload="gups")).run()

Migration table (old call -> new call):

=====================================================  =========================================
old                                                    new
=====================================================  =========================================
``engine.BATCH_ENGINES[name]``                         ``registry.ENGINES.get(name)``
``engine.make_engine(name, cfg, tier)``                ``registry.ENGINES`` + ``TieringEngine``
                                                       wrapper (or keep ``make_engine``; it now
                                                       resolves through the registry)
``workloads._BUILDERS[name]``                          ``registry.WORKLOADS.get(name)``
``simulator.MACHINES[name]``                           ``registry.MACHINES.get(name)``
hardcoded ``sampler in ("elementwise", "sparse")``     ``registry.SAMPLERS.get(name)``
hardcoded ``backend in ("numpy", "jax")``              ``registry.BACKENDS.get(name)``
=====================================================  =========================================

Builtin components are registered when their defining module is imported
(``repro.core.engine``, ``repro.core.workloads``, ``repro.core.simulator``);
importing ``repro.core`` (or ``repro.core.specs``) pulls all of them in.
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import (Any, Callable, Dict, Generic, Iterator, List, Optional,
                    Tuple, TypeVar)

T = TypeVar("T")


class Registry(Generic[T]):
    """A named component table with decorator registration and fuzzy errors."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, T] = {}

    # -- registration ------------------------------------------------------
    def register(self, name: str, obj: Optional[T] = None, *,
                 overwrite: bool = False):
        """Register ``obj`` under ``name``; usable as a decorator.

        ``registry.register("foo", thing)`` registers directly;
        ``@registry.register("foo")`` registers the decorated object.
        Duplicate names raise unless ``overwrite=True``.
        """
        if not isinstance(name, str) or not name:
            raise TypeError(f"{self.kind} name must be a non-empty string, "
                            f"got {name!r}")

        def _add(o: T) -> T:
            if name in self._entries and not overwrite:
                raise ValueError(
                    f"{self.kind} {name!r} is already registered "
                    f"(to {self._entries[name]!r}); pass overwrite=True "
                    f"to replace it")
            self._entries[name] = o
            return o

        return _add if obj is None else _add(obj)

    def unregister(self, name: str) -> None:
        """Remove ``name`` (KeyError with suggestions if absent).  Mainly
        for tests that register throwaway components."""
        if name not in self._entries:
            raise KeyError(self.unknown_message(name))
        del self._entries[name]

    _MISSING = object()

    # -- lookup ------------------------------------------------------------
    def get(self, name: str, default: Any = _MISSING) -> T:
        """Resolve ``name``.  Unlike ``dict.get``, a bare ``get(name)``
        RAISES ``KeyError`` (with a did-you-mean hint) on unknown names —
        pass an explicit ``default`` for dict-style fallback."""
        try:
            return self._entries[name]
        except (KeyError, TypeError):
            if default is not Registry._MISSING:
                return default
            raise KeyError(self.unknown_message(name)) from None

    def unknown_message(self, name: Any) -> str:
        close = difflib.get_close_matches(str(name), list(self._entries),
                                          n=1, cutoff=0.5)
        hint = f"; did you mean {close[0]!r}?" if close else ""
        have = ", ".join(sorted(self._entries)) or "<none>"
        return f"unknown {self.kind} {name!r}{hint} (registered: {have})"

    # -- dict-like views ---------------------------------------------------
    def names(self) -> List[str]:
        return sorted(self._entries)

    def items(self) -> List[Tuple[str, T]]:
        return sorted(self._entries.items())

    def values(self) -> List[T]:
        return [v for _, v in self.items()]

    def keys(self) -> List[str]:
        return self.names()

    def __getitem__(self, name: str) -> T:
        return self.get(name)

    def __setitem__(self, name: str, obj: T) -> None:
        """Dict-style assignment == ``register(..., overwrite=True)`` (kept
        for legacy callers that mutated the old module-level dicts)."""
        self.register(name, obj, overwrite=True)

    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {self.names()})"


# ---------------------------------------------------------------------------
# The registries.  Values:
#   ENGINES   — BatchTieringEngine subclasses (batched protocol classes)
#   WORKLOADS — WorkloadBuilder wrappers around builder functions
#   SAMPLERS  — draw(rng, base_counts, period) -> sampled per-page counts
#   BACKENDS  — zero-arg factory returning the vectorized access-cost callable
#   MACHINES  — Machine profiles (paper Table 3 et al.)
# ---------------------------------------------------------------------------
ENGINES: Registry[type] = Registry("engine")
WORKLOADS: "Registry[WorkloadBuilder]" = Registry("workload")
SAMPLERS: Registry[Callable[..., Any]] = Registry("sampler")
BACKENDS: Registry[Callable[[], Callable[..., Any]]] = Registry("backend")
MACHINES: Registry[Any] = Registry("machine")


def register_engine(name: str, *, space: Any = None, overwrite: bool = False):
    """Class decorator registering a batched tiering engine under ``name``.

    ``space`` optionally registers the engine's :class:`~repro.core.knobs.
    KnobSpace` so ``get_space(name)`` / ``Study.tune()`` work for it.
    """
    def deco(batch_cls: type) -> type:
        ENGINES.register(name, batch_cls, overwrite=overwrite)
        if space is not None:
            from .knobs import SPACES
            SPACES[name] = space
        return batch_cls
    return deco


@dataclasses.dataclass(frozen=True)
class WorkloadBuilder:
    """A registered workload builder plus its default input name."""

    name: str
    builder: Callable[..., Any]     # (input_name, threads, scale, seed)
    default_input: str = ""

    def __call__(self, input_name: str, threads: int, scale: float,
                 seed: int):
        # no per-field defaults here: make_workload owns them (single source)
        return self.builder(input_name or self.default_input, threads, scale,
                            seed)


def register_workload(name: str, *, default_input: str = "",
                      overwrite: bool = False):
    """Decorator registering a workload builder ``(input, threads, scale,
    seed) -> Workload`` under ``name``."""
    def deco(builder: Callable[..., Any]) -> Callable[..., Any]:
        WORKLOADS.register(name, WorkloadBuilder(name, builder, default_input),
                           overwrite=overwrite)
        return builder
    return deco


def register_sampler(name: str, fn: Optional[Callable[..., Any]] = None, *,
                     overwrite: bool = False):
    """Register a monitoring sampler ``draw(rng, base, period) -> counts``."""
    return SAMPLERS.register(name, fn, overwrite=overwrite)


def register_backend(name: str, factory: Optional[Callable[[], Any]] = None,
                     *, overwrite: bool = False):
    """Register an access-cost backend: a zero-arg factory returning the
    vectorized cost callable used by the simulator epoch loop."""
    return BACKENDS.register(name, factory, overwrite=overwrite)


def register_machine(machine: Any, *, overwrite: bool = False):
    """Register a :class:`~repro.core.simulator.Machine` profile by name."""
    MACHINES.register(machine.name, machine, overwrite=overwrite)
    return machine
