"""The paper's workload suite (Table 4) as synthetic access-trace generators.

Each workload produces, for every *epoch* (a fixed quantum of application
work, nominally ``epoch_ms`` of ideal-speed execution), the expected number of
cacheline accesses per 2 MiB page, split into reads and writes.  The patterns
encode exactly the behaviours the paper documents per workload:

* **GUPS** — scattered 8 GiB hot set inside 64 GiB, moving at half time;
  read-modify-write; hot pages uniformly spread over the address space
  (which is what defeats DAMON's region assumption, Fig. 12).
* **Silo / YCSB-C** — read-only; ~1 % of pages extremely hot, ~20 % warm
  (§4.2); Zipf-like within-group variation.
* **Silo / TPC-C** — insert-heavy; new pages are hot briefly and decay as the
  insert frontier advances (§4.3).
* **GapBS-BC** — iteration steps: a persistent hot core plus a per-iteration
  frontier set; Twitter input adds a tiny set of super-hot "popular node"
  pages that also take writes (§4.3, Fig. 8).
* **GapBS-PR / CC** — small hot core (rank arrays) + streaming scans over the
  cold edge pages with no reuse (§4.2, Fig. 4).
* **Btree** — write-heavy init phase growing the tree, then a uniform lookup
  phase with a small read-hot set of high-level node pages (§4.2).
* **XSBench** — small hot set allocated first (lands in fast tier by first
  touch) + a uniform bulk where every page has a similar, low access
  frequency (§4.2, Fig. 5).
* **Graph500** — construction writes then skew-free uniform BFS traffic: no
  tiering decision helps (the one workload with ~no tuning gain, Fig. 2).

``scale`` shrinks both the page count and the access volume by the same
factor (the simulator scales machine bandwidth identically) so per-page rates
— and therefore all threshold/cooling dynamics — are preserved while keeping
an f(θ) evaluation cheap enough for 100-iteration tuning sessions.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, List, Optional, Tuple

import numpy as np

from .pages import PAGE_BYTES
from .registry import WORKLOADS, register_workload

CACHELINE = 64
LINES_PER_PAGE = PAGE_BYTES // CACHELINE  # 32768 cachelines per 2 MiB page

#: accesses per second a single thread can issue at ideal (fast-tier) speed
BASE_RATE_PER_THREAD = 40e6


@dataclasses.dataclass
class Workload:
    name: str
    input_name: str
    rss_gib: float
    n_pages: int
    n_epochs: int
    epoch_ms: float
    threads: int
    mlp: float               # memory-level parallelism per thread
    compute_ms: float        # non-memory CPU floor per epoch
    scale: float
    epoch_access: Callable[[int], Tuple[np.ndarray, np.ndarray]]
    seed: int = 0            # build seed: (name, input, threads, scale, seed)
                             # fully determines the trace, so a workload can
                             # be rebuilt in batch-evaluation worker processes

    @property
    def key(self) -> str:
        return f"{self.name}:{self.input_name}" if self.input_name else self.name

    def total_accesses_per_epoch(self) -> float:
        return self.threads * BASE_RATE_PER_THREAD * (self.epoch_ms / 1e3) * self.scale


def _pages_for(rss_gib: float, scale: float) -> int:
    return max(64, int(rss_gib * (2 ** 30) / PAGE_BYTES * scale))


def _norm(weights: np.ndarray) -> np.ndarray:
    s = weights.sum()
    return weights / s if s > 0 else weights


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

@register_workload("gups", default_input="8GiB-hot")
def _gups(input_name: str, threads: int, scale: float, seed: int) -> Workload:
    rss = 64.03
    n = _pages_for(rss, scale)
    n_epochs = 60
    epoch_ms = 500.0
    rng = np.random.default_rng(seed + 17)
    hot_frac = 8.0 / 64.0
    n_hot = max(8, int(n * hot_frac))
    # hot pages scattered uniformly over the address space (defeats DAMON)
    hot1 = rng.choice(n, size=n_hot, replace=False)
    hot2 = rng.choice(n, size=n_hot, replace=False)
    A = threads * BASE_RATE_PER_THREAD * (epoch_ms / 1e3) * scale

    base = np.full(n, 0.10 / n)
    w1 = base.copy(); w1[hot1] += 0.90 / n_hot
    w2 = base.copy(); w2[hot2] += 0.90 / n_hot

    def epoch_access(e: int):
        w = w1 if e < n_epochs // 2 else w2
        acc = A * w
        # GUPS = read-modify-write updates: reads ~= writes
        return 0.5 * acc, 0.5 * acc

    return Workload("gups", input_name, rss, n, n_epochs, epoch_ms, threads,
                    mlp=8.0, compute_ms=40.0, scale=scale,
                    epoch_access=epoch_access, seed=seed)


@register_workload("silo", default_input="ycsb-c")
def _silo(input_name: str, threads: int, scale: float, seed: int) -> Workload:
    rss = 71.40 if input_name == "ycsb-c" else 75.68
    n = _pages_for(rss, scale)
    n_epochs = 100
    epoch_ms = 500.0
    rng = np.random.default_rng(seed + 23)
    A = threads * BASE_RATE_PER_THREAD * (epoch_ms / 1e3) * scale

    if input_name == "ycsb-c":
        # ~1% extremely hot, ~20% warm, rest cold (§4.2); read-only.
        # Exact group traffic shares: hot 0.75, warm 0.15, cold 0.10.
        n_hot = max(4, n // 100)
        n_warm = max(8, n // 5)
        perm = rng.permutation(n)
        hot, warm = perm[:n_hot], perm[n_hot:n_hot + n_warm]
        w = np.zeros(n)
        cold_mask = np.ones(n, dtype=bool)
        cold_mask[hot] = cold_mask[warm] = False
        w[cold_mask] = 0.10 / max(int(cold_mask.sum()), 1)
        vw = 1.0 + 0.5 * rng.uniform(size=n_warm)
        w[warm] = 0.15 * vw / vw.sum()
        vh = 1.0 / (1.0 + 0.05 * np.arange(n_hot))
        w[hot] = 0.75 * vh / vh.sum()
        w = _norm(w)

        def epoch_access(e: int):
            acc = A * w
            return 0.995 * acc, 0.005 * acc  # read-only workload

        compute = 60.0
    elif input_name == "tpc-c":
        # insert-heavy; hotness decays with page age as the frontier advances
        tau = n / 20.0

        def epoch_access(e: int):
            frontier = (e + 1) / n_epochs * n
            age = frontier - np.arange(n)
            w = np.where((age > 0), np.exp(-np.maximum(age, 0.0) / tau), 0.0)
            # pages just being written (age in [0, n/n_epochs)) are hottest
            w = _norm(w + 1e-9)
            acc = A * w
            return 0.55 * acc, 0.45 * acc

        compute = 150.0
    else:
        raise ValueError(f"unknown silo input {input_name!r}")

    return Workload("silo", input_name, rss, n, n_epochs, epoch_ms, threads,
                    mlp=6.0, compute_ms=compute, scale=scale,
                    epoch_access=epoch_access, seed=seed)


def _gapbs(kind: str, input_name: str, threads: int, scale: float,
           seed: int) -> Workload:
    rss = {
        ("bc", "kron"): 78.13, ("bc", "twitter"): 13.08,
        ("pr", "kron"): 71.29, ("pr", "twitter"): 12.32,
        ("cc", "kron"): 69.29, ("cc", "twitter"): 12.09,
    }[(kind, input_name)]
    n = _pages_for(rss, scale)
    n_iters = 8
    epochs_per_iter = 15 if kind == "bc" else 10
    n_epochs = n_iters * epochs_per_iter
    epoch_ms = 500.0
    rng = np.random.default_rng(seed + 31)
    A = threads * BASE_RATE_PER_THREAD * (epoch_ms / 1e3) * scale

    # persistent hot core: vertex/rank arrays (allocated first -> low indices)
    n_core = max(8, int(n * (0.20 if kind == "bc" else 0.03)))
    core = np.arange(n_core)
    # a handful of very hot pages (top-degree vertices' rank entries)
    n_super = max(4, n // 300)
    # per-iteration frontier sets (BC only): different random pages each iter
    frontiers = [rng.choice(np.arange(n_core, n), size=max(4, int(n * 0.08)),
                            replace=False) for _ in range(n_iters)]
    # twitter: tiny super-popular set, also written (centrality updates)
    n_pop = max(2, n // 200) if input_name == "twitter" else 0
    popular = rng.choice(n_core, size=n_pop, replace=False) if n_pop else None

    def epoch_access(e: int):
        it = min(e // epochs_per_iter, n_iters - 1)
        w = np.full(n, 1e-12)
        if kind == "bc":
            # the per-iteration frontier carries most of the traffic: placing
            # it fast AND on time is what separates good from bad configs
            w[:n_super] += 0.10 / n_super
            w[core] += 0.28 / n_core
            f = frontiers[it]
            w[f] += 0.46 / len(f)
            w += 0.16 / n
            reads, writes = 0.90, 0.10
        else:  # pr / cc: small hot core + streaming scan with no reuse
            w[core] += 0.30 / n_core
            w += 0.05 / n
            # streaming window over the cold region this epoch
            pos = e % epochs_per_iter
            cold_lo, cold_n = n_core, n - n_core
            win = max(1, cold_n // epochs_per_iter)
            lo = cold_lo + pos * win
            hi = min(lo + win, n)
            w[lo:hi] += 0.65 / max(hi - lo, 1)
            reads, writes = (0.85, 0.15) if kind == "pr" else (0.92, 0.08)
        if popular is not None:
            w[popular] += 0.25 / len(popular)
        w = _norm(w)
        acc = A * w
        return reads * acc, writes * acc

    return Workload(f"gapbs-{kind}", input_name, rss, n, n_epochs, epoch_ms,
                    threads, mlp=7.0, compute_ms=180.0, scale=scale,
                    epoch_access=epoch_access, seed=seed)


@register_workload("btree")
def _btree(input_name: str, threads: int, scale: float, seed: int) -> Workload:
    rss = 12.13
    n = _pages_for(rss, scale)
    n_epochs = 100
    init_epochs = int(n_epochs * 0.30)
    epoch_ms = 500.0
    rng = np.random.default_rng(seed + 41)
    # btree is pointer-chasing: low memory-level parallelism, moderate rate
    A = 0.4 * threads * BASE_RATE_PER_THREAD * (epoch_ms / 1e3) * scale
    # high-level node pages: created early (low indices -> fast tier by
    # first touch); 1% of pages take 50% of lookup reads
    n_top = max(4, n // 100)
    top = rng.choice(max(8, n // 5), size=n_top, replace=False)
    # random inserts cluster into "active split regions" that rotate:
    # those pages are write-hot for an epoch, then go quiet
    n_active = max(4, n // 26)
    actives = [rng.choice(n, size=n_active, replace=False)
               for _ in range(init_epochs)]

    def epoch_access(e: int):
        if e < init_epochs:
            # insert phase: inserts READ the lookup path (top-level nodes +
            # interior pages) but WRITE the rotating leaf/split regions: the
            # active pages are write-hot and read-cold, which is what makes
            # write_hot_threshold / write_sampling_period the decisive knobs
            # (§4.2: "decrease importance of write-heavy pages")
            grown = max(n_top * 2, int((e + 1) / init_epochs * n))
            wr = np.zeros(n)
            wr[:grown] = 0.55 / grown      # path reads over interior pages
            wr[top] += 0.45 / n_top        # top levels on every insert
            wr = _norm(wr)
            ww = np.zeros(n)
            act = actives[e][actives[e] < grown]
            if len(act) == 0:
                act = np.arange(min(grown, n_active))
            ww[act] = 0.80 / len(act)      # active split regions
            ww[:grown] += 0.20 / grown     # rebalance writes
            ww = _norm(ww)
            return 0.75 * A * wr, 0.25 * A * ww
        else:
            # lookup phase: top nodes very hot, leaves uniform
            w = np.full(n, 0.50 / n)
            w[top] += 0.50 / n_top
            w = _norm(w)
            acc = A * w
            return 0.98 * acc, 0.02 * acc

    return Workload("btree", input_name, rss, n, n_epochs, epoch_ms, threads,
                    mlp=4.0, compute_ms=60.0, scale=scale,
                    epoch_access=epoch_access, seed=seed)


@register_workload("xsbench")
def _xsbench(input_name: str, threads: int, scale: float, seed: int) -> Workload:
    rss = 64.97
    n = _pages_for(rss, scale)
    n_epochs = 80
    epoch_ms = 500.0
    rng = np.random.default_rng(seed + 47)
    A = threads * BASE_RATE_PER_THREAD * (epoch_ms / 1e3) * scale
    # unionized energy grid allocated first: hot pages are the low indices,
    # so first-touch already places them in the fast tier (§4.2, Fig. 5)
    n_hot = max(8, n * 2 // 100)
    # the bulk has "very similar" (but not identical) access counts — the
    # mild lognormal tail is what makes the default config keep promoting
    # bulk pages that are no better than the ones they displace
    bulk_w = np.exp(rng.normal(0.0, 0.3, size=n))
    bulk_w[:n_hot] = 0.0
    bulk_w = 0.55 * bulk_w / bulk_w.sum()
    base_w = bulk_w.copy()
    base_w[:n_hot] += 0.45 / n_hot
    base_w = _norm(base_w)

    def epoch_access(e: int):
        acc = A * base_w
        return 0.95 * acc, 0.05 * acc

    return Workload("xsbench", input_name, rss, n, n_epochs, epoch_ms, threads,
                    mlp=7.0, compute_ms=200.0, scale=scale,
                    epoch_access=epoch_access, seed=seed)


@register_workload("wset", default_input="f50")
def _wset(input_name: str, threads: int, scale: float, seed: int) -> Workload:
    """Parameterizable working-set workload (the drift zoo's growth/shrink
    base): input ``f<percent>`` sets the touched fraction of the address
    space (``f25`` = the first 25 % of pages are active).

    The active region is a PREFIX of the page range, so two builds at
    different fractions are strict sub/supersets of each other — exactly
    the semantics working-set growth needs (``DriftSpec.wset`` splices
    ``f25 -> f50 -> f100`` phases): when the set grows, the new pages are
    cold-start demand the tiering engine must notice and promote.  Per-page
    weights within the active set carry a mild lognormal skew drawn once
    over the FULL page range (seed-deterministic), so every fraction sees
    the same per-page weights on the shared prefix.
    """
    rss = 32.0
    n = _pages_for(rss, scale)
    n_epochs = 60
    epoch_ms = 500.0
    if not (len(input_name) > 1 and input_name[0] == "f"):
        raise ValueError(f"wset input must be 'f<percent>' (e.g. 'f25'), "
                         f"got {input_name!r}")
    frac = float(input_name[1:]) / 100.0
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"wset fraction must be in (0, 100], "
                         f"got {input_name!r}")
    rng = np.random.default_rng(seed + 53)
    A = threads * BASE_RATE_PER_THREAD * (epoch_ms / 1e3) * scale
    n_act = max(8, int(round(n * frac)))
    # one weight draw for the whole range; fractions share the prefix
    v = np.exp(rng.normal(0.0, 0.4, size=n))
    w = np.full(n, 0.05 / n)
    w[:n_act] += 0.95 * v[:n_act] / v[:n_act].sum()
    w = _norm(w)

    def epoch_access(e: int):
        acc = A * w
        return 0.90 * acc, 0.10 * acc

    return Workload("wset", input_name, rss, n, n_epochs, epoch_ms, threads,
                    mlp=7.0, compute_ms=80.0, scale=scale,
                    epoch_access=epoch_access, seed=seed)


@register_workload("graph500", default_input="kron")
def _graph500(input_name: str, threads: int, scale: float, seed: int) -> Workload:
    rss = 34.13
    n = _pages_for(rss, scale)
    n_epochs = 80
    build_epochs = int(n_epochs * 0.25)
    epoch_ms = 500.0
    A = threads * BASE_RATE_PER_THREAD * (epoch_ms / 1e3) * scale

    def epoch_access(e: int):
        if e < build_epochs:
            # construction: kronecker edges land at *random* positions, so the
            # build writes are scattered uniformly — no page is write-hot
            w = np.full(n, 1.0 / n)
            acc = 0.10 * A * w
            return 0.30 * acc, 0.70 * acc
        # BFS: skew-free uniform random — every page has the same frequency,
        # so every placement yields the same hit rate: nothing for tiering to
        # exploit (the one workload with ~no tuning gain, Fig. 2)
        w = np.full(n, 1.0 / n)
        acc = 0.12 * A * w
        return 0.97 * acc, 0.03 * acc

    return Workload("graph500", input_name, rss, n, n_epochs, epoch_ms,
                    threads, mlp=8.0, compute_ms=600.0, scale=scale,
                    epoch_access=epoch_access, seed=seed)


# ---------------------------------------------------------------------------
# registration (the gapbs builders share one parameterized function)
# ---------------------------------------------------------------------------
for _kind in ("bc", "pr", "cc"):
    register_workload(f"gapbs-{_kind}", default_input="kron")(
        functools.partial(_gapbs, _kind))

#: the paper's default benchmark set (Table 4) with its default inputs
PAPER_SUITE: List[Tuple[str, str]] = [
    ("gapbs-bc", "kron"), ("gapbs-pr", "kron"), ("gapbs-cc", "kron"),
    ("silo", "ycsb-c"), ("btree", ""), ("xsbench", ""),
    ("gups", "8GiB-hot"), ("graph500", "kron"),
]


def make_workload(name: str, input_name: str = "", threads: int = 12,
                  scale: float = 0.25, seed: int = 0) -> Workload:
    """Build the registered workload ``name`` (registry-resolved)."""
    return WORKLOADS.get(name)(input_name, threads, scale, seed)
