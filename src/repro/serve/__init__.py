from .step import build_prefill_step, build_serve_step

__all__ = ["build_prefill_step", "build_serve_step"]
