"""serve-side step builders.

* prefill_step: full-sequence forward, returns last-position logits (the
  full-vocab logits tensor for 32k x 256k would be ~0.5 TB — never built).
* serve_step: one decode step against the KV cache (the shape grid's
  ``decode_32k`` / ``long_500k`` cells lower THIS, not train_step).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


def build_prefill_step(cfg: ModelConfig, use_flash: bool = True) -> Callable:
    def prefill_step(params, batch):
        x, _ = T.hidden_forward(params, cfg, batch["tokens"],
                                batch.get("extra"), use_flash)
        last = x[:, -1:]
        unembed = params.get("unembed")
        W = unembed if unembed is not None else params["embed"].T
        logits = last @ W
        if cfg.final_softcap > 0:
            from repro.models import layers as L
            logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
        return logits
    return prefill_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, tokens (B,1), position scalar, cache) ->
    (next_tokens (B,1), logits, cache)."""
    def serve_step(params, tokens, position, cache):
        logits, cache = T.decode_step(params, cfg, tokens, position, cache)
        nxt = logits[:, -1:].argmax(-1).astype(jnp.int32)
        return nxt, logits, cache
    return serve_step
