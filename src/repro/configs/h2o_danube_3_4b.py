"""h2o-danube-3-4b [dense]: llama+mistral mix, sliding-window attention
[arXiv:2401.16818] -> sub-quadratic, long_500k runs."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="h2o-danube-3-4b", family="lm",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab=32000, head_dim=120, act="swiglu", norm="rms",
    window=4096, layer_pattern=tuple(["attn_local"] * 24),
    subquadratic=True)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=32, layer_pattern=("attn_local",) * 2,
        remat=False)
