"""whisper-base [audio]: enc-dec, conv frontend stub [arXiv:2212.04356].

The audio frontend (mel conv stack) is a STUB: input_specs() provides
precomputed frame embeddings of shape (batch, enc_ctx, d_model).
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, head_dim=64, act="gelu", norm="ln",
    enc_layers=6, enc_ctx=1500, tie_embeddings=True)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256, enc_ctx=32, remat=False)
