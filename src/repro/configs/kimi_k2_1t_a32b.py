"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2 paper-table]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="kimi-k2-1t-a32b", family="lm",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=112, act="swiglu", norm="rms",
    moe_experts=384, moe_top_k=8)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=256, moe_experts=8, moe_top_k=2, remat=False)
