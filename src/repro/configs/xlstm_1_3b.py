"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517] ->
recurrent, long_500k runs.  Attention-free: KV tiering inapplicable
(DESIGN.md §Arch-applicability)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="xlstm-1.3b", family="lm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=512, act="swiglu", norm="rms",
    layer_pattern=tuple("slstm" if i % 8 == 7 else "mlstm"
                        for i in range(48)),
    subquadratic=True)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        vocab=256, layer_pattern=("mlstm", "slstm"), remat=False)
