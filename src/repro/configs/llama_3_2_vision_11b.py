"""llama-3.2-vision-11b [vlm]: cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].  Vision frontend is a stub:
input_specs provides precomputed patch embeddings."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, head_dim=128, act="swiglu", norm="rms",
    cross_attn_every=5, n_patches=1601, vision_dim=1280)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, cross_attn_every=2, n_patches=16, vision_dim=32,
        remat=False)
