"""granite-moe-1b-a400m [moe]: 32 experts top-8 [hf:ibm-granite]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="granite-moe-1b-a400m", family="lm",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64, act="swiglu", norm="rms",
    moe_experts=32, moe_top_k=8)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=256, moe_experts=4, moe_top_k=2, remat=False)
