"""command-r-plus-104b [dense]: GQA, no-bias [hf:CohereForAI]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="command-r-plus-104b", family="lm",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab=256000, head_dim=128, act="swiglu", norm="rms",
    tie_embeddings=True)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab=256, remat=False)
