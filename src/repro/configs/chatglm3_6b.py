"""chatglm3-6b [dense]: 2d (partial) RoPE, GQA kv=2 [arXiv:2406.12793]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="chatglm3-6b", family="lm",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=65024, head_dim=128, act="swiglu", norm="rms",
    rotary_frac=0.5)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, remat=False)
