"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2
[arXiv:2402.19427] -> sub-quadratic, long_500k runs."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-2b", family="lm",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256, act="geglu", norm="rms",
    window=2048,
    layer_pattern=tuple("attn_local" if i % 3 == 2 else "rglru"
                        for i in range(26)),
    subquadratic=True)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, window=32,
        layer_pattern=("rglru", "rglru", "attn_local"), remat=False)
