"""gemma2-9b [dense]: local+global alternating, logit softcap
[arXiv:2408.00118]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="gemma2-9b", family="lm",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab=256000, head_dim=256, act="geglu", norm="rms",
    window=4096,
    layer_pattern=tuple("attn_local" if i % 2 == 0 else "attn"
                        for i in range(42)),
    attn_softcap=50.0, final_softcap=30.0)


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=32,
        layer_pattern=("attn_local", "attn"), remat=False)
