"""Architecture configs: one module per assigned arch (+ shapes).

Each module exports CONFIG (the exact published configuration) and
smoke_config() (a reduced same-family config for CPU tests).
"""
from importlib import import_module

ARCHS = [
    "whisper_base", "granite_moe_1b_a400m", "kimi_k2_1t_a32b",
    "command_r_plus_104b", "h2o_danube_3_4b", "gemma2_9b", "chatglm3_6b",
    "recurrentgemma_2b", "xlstm_1_3b", "llama_3_2_vision_11b",
]

#: --arch <id> aliases (dashes/dots as in the assignment table)
ALIASES = {
    "whisper-base": "whisper_base",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "command-r-plus-104b": "command_r_plus_104b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "gemma2-9b": "gemma2_9b",
    "chatglm3-6b": "chatglm3_6b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
}


def get_config(arch: str, smoke: bool = False):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.CONFIG


def all_arch_ids():
    return list(ALIASES)
