"""Optimizers + schedules + distributed-optimization tricks (pure JAX).

* AdamW — fp32 moments, decoupled weight decay.
* Adafactor-lite — factored second moment, no first moment: the optimizer
  states for the 1T-param kimi-k2 config fit in HBM (AdamW's 8 TB/pod of
  moments would not).
* cosine schedule with linear warmup.
* global-norm clipping.
* error-feedback int8 gradient compression for the DCN ("pod") axis —
  compress-allreduce-decompress with residual carry, used by the Trainer
  when pods > 1.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params):
        f32 = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(f32, params),
                          jax.tree.map(f32, params))

    def state_specs(self, param_specs):
        from jax.sharding import PartitionSpec as P
        return AdamWState(P(), param_specs, param_specs)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:   # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_m, new_v)


# ---------------------------------------------------------------------------
# Adafactor-lite (factored second moment, momentum-free)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any    # row factors (or full v for <2D params)
    vc: Any    # col factors (or None-placeholders)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable | float = 1e-3
    decay: float = 0.99
    eps: float = 1e-30
    weight_decay: float = 0.0

    def _factored(self, p):
        return p.ndim >= 2

    def init(self, params):
        def vr(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, jnp.float32)

        def vc(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)
        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(vr, params),
                              jax.tree.map(vc, params))

    def state_specs(self, param_specs):
        from jax.sharding import PartitionSpec as P

        def vr_spec(s):
            parts = tuple(s) if s else ()
            return P(*parts[:-1]) if len(parts) >= 2 else s

        def vc_spec(s):
            parts = tuple(s) if s else ()
            return P(*(parts[:-2] + parts[-1:])) if len(parts) >= 2 else P(None)
        return AdafactorState(
            P(),
            jax.tree.map(vr_spec, param_specs,
                         is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(vc_spec, param_specs,
                         is_leaf=lambda x: isinstance(x, P)))

    def update(self, grads, state: AdafactorState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        d = self.decay

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if self._factored(p):
                vr = d * vr + (1 - d) * g2.mean(axis=-1)
                vc = d * vc + (1 - d) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], self.eps))
                pre = g * jax.lax.rsqrt(jnp.maximum(denom, self.eps))
            else:
                vr = d * vr + (1 - d) * g2
                pre = g * jax.lax.rsqrt(jnp.maximum(vr, self.eps))
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(pre * pre) + 1e-12)
            pre = pre / jnp.maximum(1.0, rms)
            newp = p.astype(jnp.float32) - lr * pre
            if self.weight_decay and p.ndim >= 2:
                newp = newp - lr * self.weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), vr, vc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        istup = lambda t: isinstance(t, tuple)
        return (jax.tree.map(lambda t: t[0], out, is_leaf=istup),
                AdafactorState(step,
                               jax.tree.map(lambda t: t[1], out, is_leaf=istup),
                               jax.tree.map(lambda t: t[2], out, is_leaf=istup)))


def make_optimizer(name: str, lr_schedule=None, **kw):
    lr = lr_schedule if lr_schedule is not None else 3e-4
    if name == "adamw":
        return AdamW(lr=lr, **kw)
    if name == "adafactor":
        return Adafactor(lr=lr, **kw)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# error-feedback int8 gradient compression (for the DCN / "pod" axis)
# ---------------------------------------------------------------------------

def ef_compress(g, residual):
    """Returns (int8_payload, scale, new_residual_base).  The caller
    all-reduces the int8 payload across pods, then calls ef_decompress."""
    x = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_residual = x - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def ef_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, residual, axis_name: str):
    """EF-int8 all-reduce over ``axis_name`` (used for the pod axis)."""
    q, scale, new_res = ef_compress(g, residual)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (q_sum.astype(jnp.float32) * scale_max / n), new_res
