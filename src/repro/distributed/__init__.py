"""Mesh-axis conventions + sharding-spec resolution.

Physical mesh axes:
  single pod:  ("data", "model")           = (16, 16) on v5e
  multi-pod:   ("pod", "data", "model")    = (2, 16, 16)

Logical convention used by every layer's specs:
  "data"  — DP/FSDP: batch + parameter sharding (ZeRO-3 style; XLA SPMD
            inserts the all-gathers / reduce-scatters)
  "model" — TP/EP: attention heads, MLP hidden, expert and vocab dims
  "pod"   — outer data parallelism: batch is additionally split across pods;
            parameters are replicated per pod, so gradients all-reduce over
            DCN (optionally EF-int8-compressed, see optim.compressed_psum)

Params never mention "pod": unlisted mesh axes replicate, which is exactly
the per-pod replica layout.  Batches shard over ("pod","data") jointly.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    return P(dp_axes(mesh), *([None] * extra_dims))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, specs):
    """Map a pytree of PartitionSpecs to NamedShardings."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def dp_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def abstract_params(init_fn, key, cfg, mesh: Mesh, specs):
    """Shape-only params with shardings attached (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_fn(k, cfg)[0], key)
    sh = tree_shardings(mesh, specs)
    return jax.tree.map(
        lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
        shapes, sh)


def validate_divisibility(cfg, shape, mesh: Mesh) -> Optional[str]:
    """Explain-early check: does this (arch x shape x mesh) cell divide?"""
    dp = dp_size(mesh)
    if shape.global_batch % dp and shape.global_batch >= dp:
        return f"global_batch {shape.global_batch} % dp {dp} != 0"
    return None
