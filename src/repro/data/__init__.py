from .pipeline import SyntheticLM, DataState

__all__ = ["SyntheticLM", "DataState"]
