"""Deterministic synthetic data pipeline.

Design constraints for 1000+-node training:
  * step-indexed PRNG — batch(step) is a pure function, so a restarted or
    elastically-rescaled job resumes mid-epoch with byte-identical data and
    no shared reader state;
  * per-host sharding — each host materializes only its slice of the global
    batch (`host_slice`), and the launcher device_puts it with the batch
    sharding, so no host ever holds the full global batch;
  * double-buffered prefetch — `prefetch()` yields batch(step+1) while the
    device works on batch(step).

The generator produces a mixture of Zipf-distributed unigrams and short
Markov "phrases" so losses are non-trivial (models can actually learn), with
masked (-1) labels at document boundaries.
"""

from __future__ import annotations

import dataclasses
import threading
from queue import Queue
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataState:
    """Resume token: everything needed to regenerate the stream."""
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d):
        return DataState(int(d["seed"]), int(d["step"]))


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, extra_shape: Optional[Tuple[int, ...]] = None):
        self.vocab = int(vocab)
        self.seq_len = int(seq_len)
        self.global_batch = int(global_batch)
        self.seed = int(seed)
        self.extra_shape = extra_shape
        # fixed Markov structure (derived from seed, not from step)
        r = np.random.default_rng(seed ^ 0x5EED)
        self._n_states = 64
        self._trans = r.integers(0, vocab, size=(self._n_states, 8))

    # -- pure batch(step) ----------------------------------------------------
    def batch_at(self, step: int, lo: int = 0,
                 hi: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Rows [lo, hi) of the global batch for `step` (host slice)."""
        hi = self.global_batch if hi is None else hi
        rows = []
        for b in range(lo, hi):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 4099 + b)
            toks = self._row(rng)
            rows.append(toks)
        tokens = np.stack(rows).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((tokens.shape[0], 1), -1, np.int32)],
            axis=1)
        out = {"tokens": tokens, "labels": labels}
        if self.extra_shape is not None:
            rng = np.random.default_rng(self.seed * 7919 + step)
            out["extra"] = (rng.standard_normal(
                (hi - lo,) + self.extra_shape[1:]) * 0.02).astype(np.float32)
        return out

    def _row(self, rng) -> np.ndarray:
        S = self.seq_len
        out = np.empty(S, np.int64)
        i = 0
        state = int(rng.integers(self._n_states))
        while i < S:
            if rng.random() < 0.3:   # zipf unigram burst
                n = min(int(rng.integers(1, 8)), S - i)
                z = rng.zipf(1.3, size=n)
                out[i:i + n] = np.minimum(z, self.vocab - 1)
                i += n
            else:                     # markov phrase
                n = min(int(rng.integers(2, 12)), S - i)
                for j in range(n):
                    tok = self._trans[state, int(rng.integers(8))]
                    out[i + j] = tok
                    state = int(tok) % self._n_states
                i += n
        return out

    # -- iteration with prefetch ----------------------------------------------
    def iterate(self, state: DataState, lo: int = 0,
                hi: Optional[int] = None,
                prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
        q: Queue = Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = state.step
            while not stop.is_set():
                q.put((step, self.batch_at(step, lo, hi)))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                step, batch = q.get()
                yield step, batch
        finally:
            stop.set()
