"""Sharded, atomic, async checkpointing.

Layout:
  <dir>/step_000100/
      manifest.json          — tree structure, dtypes, shapes, data state
      shard_00000.npz        — flat leaves (this host's slice)
  <dir>/LATEST               — atomically renamed pointer file

Guarantees:
  * atomicity: writes go to step_X.tmp-<nonce>/ then os.replace() — a crash
    mid-save never corrupts the previous checkpoint, and LATEST flips last;
  * async: save() returns immediately; the writer thread drains on exit or
    on the next save (back-pressure of 1 in flight);
  * restore into a DIFFERENT mesh/device-count (elastic restart): leaves are
    saved as full logical arrays per host shard and re-sharded on load via
    jax.device_put with the new sharding.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree, aux: Optional[dict] = None):
    """Synchronous sharded save with atomic rename."""
    leaves, treedef = _flatten(tree)
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    arrays = {}
    dtypes = {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes[f"leaf_{i}"] = str(arr.dtype)
        if arr.dtype.name == "bfloat16":   # npz cannot round-trip bf16
            arr = arr.astype(np.float32)
        arrays[f"leaf_{i}"] = arr
    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    manifest = {
        "step": int(step),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "aux": aux or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # flip LATEST last
    latest_tmp = os.path.join(directory, f".LATEST.tmp-{uuid.uuid4().hex[:8]}")
    with open(latest_tmp, "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def load_checkpoint(directory: str, tree_like, step: Optional[int] = None,
                    shardings=None):
    """Restore into the structure of ``tree_like``; re-shard with
    ``shardings`` (pytree of NamedSharding) if given — this is the elastic
    re-mesh path: the new mesh may have a different device count."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_00000.npz"))
    leaves, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, model {len(leaves)}"
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    import jax.numpy as jnp
    for i, (like, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"leaf_{i}"]
        if hasattr(like, "dtype") and arr.dtype != like.dtype:
            arr = jnp.asarray(arr, like.dtype)   # handles bf16 round-trip
        if sh is not None:
            arr = jax.device_put(arr, sh)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["aux"]


class CheckpointManager:
    """Async save with one in-flight write + retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def save_async(self, step: int, tree, aux: Optional[dict] = None):
        self.wait()  # back-pressure: one in flight
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, aux)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp" not in n)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
