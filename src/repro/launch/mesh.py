"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before the first jax
device query.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 (256 chips) per pod; 2 pods over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
