"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched greedy decoding against the selected architecture with a live KV
cache, optionally through the TieredKVCache (HBM/host two-tier paging with
the HeMem engine driving migrations — the paper's technique in the decode
loop).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_arch_ids, get_config
from repro.models import transformer as T
from repro.models.registry import extra_shape
from repro.serve.step import build_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b",
                    help=f"one of: {', '.join(all_arch_ids())}")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=not args.full)
    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.new_tokens + 1
    cache, _ = T.decode_init(cfg, args.batch, max_len)
    es = extra_shape(cfg, args.batch)
    if es is not None:
        cache = T.prime_cross_kv(
            params, cfg, cache,
            jax.random.normal(jax.random.PRNGKey(1), es) * 0.02)

    step = build_serve_step(cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)))
    # prefill via decode steps (teacher forcing the prompt)
    tok = prompt[:, :1]
    for t in range(args.prompt_len):
        nxt, logits, cache = step(params, prompt[:, t:t + 1], jnp.int32(t),
                                  cache)
    out_tokens = []
    t0 = time.time()
    tok = nxt
    for t in range(args.new_tokens):
        tok, logits, cache = step(params, tok,
                                  jnp.int32(args.prompt_len + t), cache)
        out_tokens.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"{cfg.arch}: generated {gen.shape} tokens "
          f"({dt / args.new_tokens * 1e3:.1f} ms/token on "
          f"{jax.default_backend()})")
    for b in range(args.batch):
        print(f"  seq{b}: {gen[b][:16].tolist()}...")


if __name__ == "__main__":
    main()
