"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant Trainer on the local devices (CPU here, TPU slice in
production — the same pjit path the dry-run proves out at 256/512 chips).
Smoke-scale by default; ``--full`` uses the published config (TPU-sized).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import all_arch_ids, get_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b",
                    help=f"one of: {', '.join(all_arch_ids())}")
    ap.add_argument("--full", action="store_true",
                    help="published config (needs a real TPU slice)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--optimizer", default=None,
                    help="adamw|adafactor (default: auto by size)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 production mesh (requires 256 devices)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=not args.full)
    opt = args.optimizer or (
        "adafactor" if cfg.param_count() > 3e11 else "adamw")
    mesh = make_production_mesh() if args.production_mesh \
        else make_local_mesh()
    print(f"{cfg.arch}: {cfg.param_count() / 1e6:.1f}M params, "
          f"optimizer={opt}, mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    tr = Trainer(cfg, mesh, args.workdir, global_batch=args.batch,
                 seq_len=args.seq, total_steps=args.steps, lr=args.lr,
                 ckpt_every=max(10, args.steps // 4), optimizer=opt)
    out = tr.run()
    for m in out["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  {m['dt'] * 1e3:.0f}ms")
    print(f"done at step {out['final_step']}; "
          f"stragglers detected: {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
