import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this produces, without allocating any model-sized buffer:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective_bytes            — parsed from compiled.as_text()
and writes benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
      --shape train_4k --mesh single                               # one cell
"""

import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, all_arch_ids
from repro.distributed import (batch_spec, dp_axes, dp_size, tree_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import SHAPES, ModelConfig
from repro.models.registry import extra_shape, shape_applicable
from repro.optim import cosine_schedule, make_optimizer
from repro.serve.step import build_prefill_step, build_serve_step
from repro.train.step import auto_microbatches, build_train_step
from repro.kernels import ops as kops

RESULTS = os.path.join(os.path.dirname(__file__),
                       "../../../benchmarks/results/dryrun")

# the dry-run lowers the portable reference attention path: its HLO is what
# cost_analysis can price (the Pallas kernels are TPU-runtime objects)
kops.FORCE = "ref"

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1}


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES.get(dt, 2)


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in optimized HLO."""
    per_kind = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "<shape> <name> = <op>(...)" — match the op on the rhs
        m = re.match(r"^(?:ROOT )?[%\w.\-]+ = (.*?) ([a-z0-9\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            if op.startswith(kind):
                shapes = _SHAPE_RE.finditer(m.group(1))
                b = sum(_shape_bytes(x) for x in shapes)
                per_kind[kind] += b
                count[kind] += 1
    total = sum(per_kind.values())
    return total, per_kind, count


def widen_dp(tree, mesh):
    """Activation/cache specs name only 'data'; on the multi-pod mesh the
    batch dimension also spans 'pod'."""
    if "pod" not in mesh.axis_names:
        return tree

    def fix(spec):
        if not isinstance(spec, P):
            return spec
        parts = tuple(("pod", "data") if a == "data" else a for a in spec)
        return P(*parts)
    return jax.tree.map(fix, tree, is_leaf=lambda x: isinstance(x, P))


def abstract(tree_shapes, tree_specs, mesh):
    sh = tree_shardings(mesh, tree_specs)
    return jax.tree.map(
        lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
        tree_shapes, sh)


def input_specs(cfg: ModelConfig, shape, mesh):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    bs = batch_spec(mesh)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32,
                               sharding=NamedSharding(mesh, P(*bs)))
    batch = {"tokens": tok, "labels": tok}
    es = extra_shape(cfg, B)
    if es is not None:
        batch["extra"] = jax.ShapeDtypeStruct(
            es, jnp.float32,
            sharding=NamedSharding(mesh, P(bs[0], *([None] * (len(es) - 1)))))
    return batch


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               smoke: bool = False):
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": ("long_500k needs sub-quadratic attention; "
                            f"{arch} is full-attention (see DESIGN.md)")}

    key = jax.random.PRNGKey(0)
    param_shapes, specs = T.shape_init(key, cfg)
    params_abs = abstract(param_shapes, specs, mesh)

    if shape.kind == "train":
        opt_name = "adafactor" if cfg.param_count() > 3e11 else "adamw"
        opt = make_optimizer(opt_name, cosine_schedule(3e-4, 100, 10000))
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        opt_abs = abstract(opt_shapes, opt.state_specs(specs), mesh)
        from repro.train.step import TrainState
        state_abs = TrainState(params_abs, opt_abs,
                               jax.ShapeDtypeStruct(
                                   (), jnp.int32,
                                   sharding=NamedSharding(mesh, P())))
        n_micro = int(os.environ.get("REPRO_N_MICRO", "0")) or \
            auto_microbatches(cfg, shape.global_batch, shape.seq_len,
                              dp_size(mesh))
        step = build_train_step(cfg, opt, n_micro=n_micro, use_flash=False)
        batch = input_specs(cfg, shape, mesh)
        fn = jax.jit(step, donate_argnums=(0,))
        args = (state_abs, batch)
        extra_info = {"optimizer": opt_name, "n_micro": n_micro,
                      "step_kind": "train_step"}
    elif shape.kind == "prefill":
        step = build_prefill_step(cfg, use_flash=False)
        batch = input_specs(cfg, shape, mesh)
        fn = jax.jit(step)
        args = (params_abs, batch)
        extra_info = {"step_kind": "prefill_step"}
    else:  # decode
        B = shape.global_batch
        cache_shapes = jax.eval_shape(
            lambda: T.decode_init(cfg, B, shape.seq_len)[0])
        _, cache_specs = T.decode_init(cfg, 1, 8)   # tiny concrete: specs only
        if B % dp_size(mesh) == 0:
            cache_specs = widen_dp(cache_specs, mesh)
            bs = P(*batch_spec(mesh))
        else:
            # long_500k runs batch=1: replicate the batch dim ("data" only
            # ever marks the batch axis in cache specs), keep the model-axis
            # sequence sharding
            cache_specs = jax.tree.map(
                lambda s: P(*(None if a == "data" else a for a in tuple(s)))
                if isinstance(s, P) else s,
                cache_specs, is_leaf=lambda x: isinstance(x, P))
            bs = P(None)
        cache_abs = abstract(cache_shapes, cache_specs, mesh)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                   sharding=NamedSharding(mesh, bs))
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        step = build_serve_step(cfg)
        fn = jax.jit(step, donate_argnums=(3,))
        args = (params_abs, tok, pos, cache_abs)
        extra_info = {"step_kind": "serve_step",
                      "kv_len": shape.seq_len}

    t0 = time.time()
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it fully
        mem["error"] = repr(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in (ca or {}).items():
            if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "optimal_seconds")
                    or k.startswith("bytes accessed")):
                cost[k] = float(v)
    except Exception as e:
        cost["error"] = repr(e)

    hlo = compiled.as_text()
    coll_total, coll_kind, coll_count = collective_bytes(hlo)

    n_chips = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem, "cost_analysis": cost,
        "collective_bytes_total": coll_total,
        "collective_bytes": coll_kind,
        "collective_count": coll_count,
        "hlo_lines": hlo.count("\n"),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        **extra_info,
    }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI sanity)")
    ap.add_argument("--opt", action="store_true",
                    help="enable the beyond-paper perf changes (sequence "
                         "parallelism + dp-sharded MoE dispatch buffers); "
                         "results tagged __opt")
    args = ap.parse_args(argv)

    if args.opt:
        from repro.models import transformer as TT, layers as LL
        if os.environ.get("REPRO_OPT_SP", "1") == "1":
            TT.set_activation_sharding(P("data", "model", None))
        if os.environ.get("REPRO_OPT_MOE", "1") == "1":
            LL.set_moe_buffer_sharding(P("model", "data", None))

    archs = all_arch_ids() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    os.makedirs(RESULTS, exist_ok=True)
    failures = []
    suffix = "__opt" if args.opt else ""
    suffix += os.environ.get("REPRO_TAG", "")
    for arch in archs:
        for shape_name in shapes:
            for mesh_name, mesh in meshes:
                tag = f"{arch}__{shape_name}__{mesh_name}{suffix}"
                t0 = time.time()
                try:
                    with mesh:
                        res = lower_cell(arch, shape_name, mesh, mesh_name,
                                         smoke=args.smoke)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "error": repr(e)}
                    failures.append(tag)
                out = os.path.join(RESULTS, f"{tag}.json")
                with open(out, "w") as f:
                    json.dump(res, f, indent=2)
                status = ("SKIP" if "skipped" in res else
                          "FAIL" if "error" in res else "OK")
                extra = ""
                if status == "OK":
                    fl = res["cost_analysis"].get("flops", 0)
                    extra = (f" flops={fl:.3g}"
                             f" coll={res['collective_bytes_total']:.3g}B"
                             f" compile={res['compile_s']}s")
                print(f"[{status}] {tag}{extra} ({time.time() - t0:.0f}s)",
                      flush=True)
    if failures:
        print(f"\n{len(failures)} FAILED cells: {failures}")
        return 1
    print("\nALL CELLS LOWERED+COMPILED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
