"""Pallas TPU exact top-k page-selection kernel: the migration planner's sort.

Every tiering engine's ``plan`` step reduces to the same primitive: given a
candidate mask and a per-page priority, pick the top ``n_promote`` hottest
promotion candidates and the top ``n_demote`` coldest demotion candidates,
breaking priority ties by page index exactly like the numpy reference's
stable sorts.  The compiled jax epoch loop used to approximate this with
8-bit log-quantized priorities (exact *counts*, near-exact order); this
kernel removes the approximation: selection is a radix-select over the full
**(priority, index)** key, bit-exact against ``np.argsort(kind="stable")``.

Per batch row (one grid step) the kernel runs three phases, all expressed as
compare + reduce passes over the row (no dense sort, no data movement):

1. **priority cutoff** — a 32-step bitwise binary search per side finds the
   k-th best order-preserving float bit pattern (promotions descend,
   demotions ascend via complemented bits);
2. **strict set** — pages strictly better than the cutoff are all selected;
3. **boundary tier** — among pages *equal* to the cutoff, a 17-step bitwise
   search over descending-index weights picks the remaining
   ``k - |strict|`` pages with the smallest indices — numpy's stable
   tie-break, exactly.

Priorities must be NaN-free; every engine's priorities are nonnegative
sample counts/rates, and the conformance suite (``tests/test_select_topk``)
pins both this kernel and the pure-jnp fallback (:func:`repro.kernels.ref.
select_topk_ref`) to the numpy stable-sort reference bit-for-bit.

The kernel is grid-parallel over batch rows; each program streams one padded
(1, n) row of packed keys through VMEM (u32 row + masks ≈ 1 MiB at the
backend's 64k-page ceiling).  On CPU it runs in interpret mode (CI); on TPU
the compare/reduce passes map onto VPU lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: bits of the index weight searched in phase 3 (page index < 2**16 by the
#: jax backend's page ceiling; padding can push the weight to 2**16, so one
#: extra bit)
_IDX_BITS = 17


def order_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Map float32 to uint32 preserving total order (NaN-free inputs):
    larger float <=> larger unsigned bit pattern."""
    bits = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jnp.where((bits >> 31) == 0, bits | np.uint32(1 << 31), ~bits)


def pack_keys(p_mask, p_heat, d_mask, d_heat):
    """Selection keys: 0 marks a non-candidate; candidates map their heat to
    order-preserving bits, complemented on the demote side so 'colder'
    ranks higher.  Candidate keys are always nonzero (heat is a NaN-free
    float, so its order bits never reach the complement's zero)."""
    vp = jnp.where(p_mask, order_bits(p_heat), np.uint32(0))
    vd = jnp.where(d_mask, ~order_bits(d_heat), np.uint32(0))
    return vp, vd


def _kernel(kp_ref, kd_ref, vp_ref, vd_ref, pm_ref, dm_ref):
    vp = vp_ref[...]                       # (1, n_pad) uint32 keys
    vd = vd_ref[...]
    kp = kp_ref[0, 0]                      # per-row selection counts (f32)
    kd = kd_ref[0, 0]

    def count_ge(v, t):
        # counts stay < 2**24, exact in f32
        return jnp.sum((v >= t).astype(jnp.float32))

    # phase 1: dual bitwise search for each side's k-th best key
    tp = jnp.uint32(0)
    td = jnp.uint32(0)
    for i in range(31, -1, -1):
        bit = np.uint32(1 << i)
        tp = jnp.where(count_ge(vp, tp | bit) >= kp, tp | bit, tp)
        td = jnp.where(count_ge(vd, td | bit) >= kd, td | bit, td)

    # phase 2: everything strictly better than the cutoff is selected
    strict_p = vp > tp
    strict_d = vd > td
    bound_p = (vp == tp) & (vp > 0)        # v > 0 excludes non-candidates
    bound_d = (vd == td) & (vd > 0)
    take_p = kp - jnp.sum(strict_p.astype(jnp.float32))
    take_d = kd - jnp.sum(strict_d.astype(jnp.float32))

    # phase 3: fill from the boundary tier in page-index order — a second
    # bitwise search over descending-index weights (weights are distinct,
    # so the take-th largest threshold selects exactly `take` pages)
    n_pad = vp.shape[-1]
    iv = np.uint32(n_pad) - lax.broadcasted_iota(jnp.uint32, vp.shape, 1)
    wp = jnp.where(bound_p, iv, np.uint32(0))
    wd = jnp.where(bound_d, iv, np.uint32(0))
    sp = jnp.uint32(0)
    sd = jnp.uint32(0)
    for i in range(_IDX_BITS - 1, -1, -1):
        bit = np.uint32(1 << i)
        sp = jnp.where(count_ge(wp, sp | bit) >= take_p, sp | bit, sp)
        sd = jnp.where(count_ge(wd, sd | bit) >= take_d, sd | bit, sd)

    pm = strict_p | (bound_p & (wp >= sp) & (take_p > 0))
    dm = strict_d | (bound_d & (wd >= sd) & (take_d > 0))
    pm_ref[...] = (pm & (kp > 0)).astype(jnp.int32)
    dm_ref[...] = (dm & (kd > 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def select_topk(p_mask, p_heat, d_mask, d_heat, n_promote, n_demote, *,
                interpret: bool = True):
    """Exact top-``n_promote`` (by ``p_heat`` desc) and top-``n_demote``
    (by ``d_heat`` asc) selection masks, ties by page index ascending.

    All array arguments are ``(B, n)`` (masks bool, heats float,
    ``n_promote``/``n_demote`` ``(B,)`` integer-valued floats); returns two
    ``(B, n)`` bool masks bit-identical to the numpy stable-sort reference.
    """
    B, n = p_mask.shape
    vp, vd = pack_keys(p_mask, p_heat, d_mask, d_heat)
    n_pad = -(-n // 128) * 128
    if n_pad != n:  # padding keys are 0 == non-candidate
        vp = jnp.pad(vp, ((0, 0), (0, n_pad - n)))
        vd = jnp.pad(vd, ((0, 0), (0, n_pad - n)))
    kp = jnp.floor(n_promote.astype(jnp.float32)).reshape(B, 1)
    kd = jnp.floor(n_demote.astype(jnp.float32)).reshape(B, 1)
    pm, dm = pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n_pad), lambda b: (b, 0)),
            pl.BlockSpec((1, n_pad), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_pad), lambda b: (b, 0)),
            pl.BlockSpec((1, n_pad), lambda b: (b, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, n_pad), jnp.int32),
                   jax.ShapeDtypeStruct((B, n_pad), jnp.int32)],
        interpret=interpret,
    )(kp, kd, vp, vd)
    return pm[:, :n] != 0, dm[:, :n] != 0
