"""Pallas TPU flash attention (tiled online softmax).

Target: TPU MXU/VMEM. Grid = (B*KV_heads, n_q_blocks, n_kv_blocks); the kv
axis is the innermost (sequential on TPU), so the online-softmax state
(m, l, acc) lives in VMEM scratch and is carried across kv steps.

BlockSpec tiling:
  q:   (1, block_q, G, D)   — one kv-head group of query rows
  k/v: (1, block_k, D)      — one kv block
  out: (1, block_q, G, D)
Working set ~ block_q*G*D + 2*block_k*D + scratch ≈ 2-4 MiB for the default
block_q = block_k = 128, G <= 16, D <= 256 — sized for ~16 MiB VMEM.

Supports causal masking, sliding windows and logit softcap (gemma2).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, softcap: float, block_q: int,
            block_k: int, seq_len: int, kv_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, G, D)
    k = k_ref[0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0].astype(jnp.float32)          # (bk, D)
    scale = 1.0 / math.sqrt(q.shape[-1])

    s = jnp.einsum("qgd,kd->qgk", q * scale, k,
                   preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1, block_k), 2)
    mask = (k_pos < kv_len) & (q_pos < seq_len)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                        # (bq, G)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=-1)
    acc = acc_scr[...] * corr[..., None] + jnp.einsum(
        "qgk,kd->qgd", p, v, preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kj == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)[..., None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_softcap", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B,S,H,D), k/v: (B,T,KV,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, S), min(block_k, T)

    qr = q.reshape(B, S, KV, G, D).transpose(0, 2, 1, 3, 4) \
          .reshape(B * KV, S, G, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)

    nq = pl.cdiv(S, bq)
    nk = pl.cdiv(T, bk)
    grid = (B * KV, nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window,
                          softcap=logit_softcap, block_q=bq, block_k=bk,
                          seq_len=S, kv_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, G, D), lambda h, i, j: (h, i, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, D), lambda h, i, j: (h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, S, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, G), jnp.float32),
            pltpu.VMEM((bq, G), jnp.float32),
            pltpu.VMEM((bq, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, S, G, D).transpose(0, 2, 1, 3, 4) \
              .reshape(B, S, H, D)
