"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests assert against, and also the
portable path used when running on CPU (including the dry-run lowering): the
flash reference uses the same online-softmax block recurrence as the kernel,
so its memory behaviour — O(S·block) instead of O(S²) — and FLOP profile
match what the TPU kernel does.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# flash attention (training/prefill)
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        logit_softcap: float = 0.0,
                        block_kv: int = 512) -> jnp.ndarray:
    """Online-softmax attention. q: (B,S,H,D), k/v: (B,T,KV,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = (q.reshape(B, S, KV, G, D).astype(jnp.float32)) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    q_pos = jnp.arange(S)

    nblk = max(1, math.ceil(T / block_kv))
    Tpad = nblk * block_kv
    kf = jnp.pad(kf, ((0, 0), (0, Tpad - T), (0, 0), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, Tpad - T), (0, 0), (0, 0)))

    def body(carry, blk_idx):
        m, l, acc = carry
        start = blk_idx * block_kv
        kb = jax.lax.dynamic_slice_in_dim(kf, start, block_kv, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vf, start, block_kv, axis=1)
        k_pos = start + jnp.arange(block_kv)
        s = jnp.einsum("bskgd,btkd->bskgt", qg, kb)
        s = _softcap(s, logit_softcap)
        mask = (k_pos[None, :] < T)[None, None, None]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])[None, :, None, None]
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)[None, :, None, None]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, KV, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, S, KV, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nblk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged decode attention (the TieredKVCache HBM side)
# ---------------------------------------------------------------------------

def paged_attention_ref(q, k_pages, v_pages, block_table, lengths,
                        *, logit_softcap: float = 0.0) -> jnp.ndarray:
    """Decode attention over a paged KV pool.

    q:           (B, H, D)         one new token per sequence
    k/v_pages:   (P, page, KV, D)  global page pool
    block_table: (B, pages_per_seq) int32 page ids (-1 = unused)
    lengths:     (B,)              current sequence lengths
    -> (B, H, D)
    """
    B, H, D = q.shape
    Pn, page, KV, _ = k_pages.shape
    G = H // KV
    ppseq = block_table.shape[1]
    scale = 1.0 / math.sqrt(D)

    table = jnp.maximum(block_table, 0)
    kk = k_pages[table]          # (B, ppseq, page, KV, D)
    vv = v_pages[table]
    kk = kk.reshape(B, ppseq * page, KV, D).astype(jnp.float32)
    vv = vv.reshape(B, ppseq * page, KV, D).astype(jnp.float32)
    qg = q.reshape(B, KV, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,btkd->bkgt", qg, kk)
    s = _softcap(s, logit_softcap)
    pos = jnp.arange(ppseq * page)[None]
    valid = (pos < lengths[:, None]) & \
        (block_table[:, pos[0] // page] >= 0)
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
    p = jnp.where(valid[:, None, None], p, 0.0)
    out = jnp.einsum("bkgt,btkd->bkgd", p, vv) \
        / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# page migration (gather/scatter datapath of the tiering engine)
# ---------------------------------------------------------------------------

def page_migrate_ref(dst_pool, src_pool, dst_ids, src_ids):
    """Copy pages src_pool[src_ids] -> dst_pool[dst_ids]; -1 ids are no-ops.

    pools: (P, page_elems) — returns updated dst_pool.
    """
    n = src_ids.shape[0]
    valid = (src_ids >= 0) & (dst_ids >= 0)
    src = jnp.where(valid, src_ids, 0)
    dst = jnp.where(valid, dst_ids, 0)
    rows = src_pool[src]
    current = dst_pool[dst]
    rows = jnp.where(valid[:, None], rows, current)
    return dst_pool.at[dst].set(rows)


# ---------------------------------------------------------------------------
# exact top-k page selection (the migration planner's sort)
# ---------------------------------------------------------------------------

def _order_bits(x: jnp.ndarray) -> jnp.ndarray:
    """float32 -> uint32 preserving total order (NaN-free inputs)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jnp.where((bits >> 31) == 0, bits | np.uint32(1 << 31), ~bits)


def select_topk_ref(p_mask, p_heat, d_mask, d_heat, n_promote, n_demote):
    """Exact top-``n_promote`` (by ``p_heat`` desc) / top-``n_demote`` (by
    ``d_heat`` asc) selection masks with page-index tie-break — bit-exact
    against numpy's stable argsorts, without a dense sort.

    The pure-jnp oracle of :mod:`repro.kernels.select_topk` and the CPU
    fast path of the compiled epoch loop: a dual 32-step bitwise search
    finds each side's k-th best order-preserving float bit pattern, strict
    winners are taken wholesale, and the boundary tier (priority exactly
    equal to the cutoff) is filled in page-index order by a second bitwise
    search over descending-index weights.  All passes are f32
    compare-count GEMVs (XLA CPU's predicate reductions are scalar, its
    GEMV is vectorized); counts stay below 2**24 so the f32 arithmetic is
    exact.  Priorities must be NaN-free (engine priorities are nonnegative
    counts/rates).
    """
    n = p_mask.shape[-1]
    ones = jnp.ones(n, jnp.float32)
    kp = jnp.floor(n_promote.astype(jnp.float32))[:, None]
    kd = jnp.floor(n_demote.astype(jnp.float32))[:, None]
    vp = jnp.where(p_mask, _order_bits(p_heat), np.uint32(0))
    vd = jnp.where(d_mask, ~_order_bits(d_heat), np.uint32(0))

    def count_ge(v, t):
        return ((v >= t).astype(jnp.float32) @ ones)[:, None]

    tp = jnp.zeros((kp.shape[0], 1), dtype=jnp.uint32)
    td = jnp.zeros((kd.shape[0], 1), dtype=jnp.uint32)
    for i in range(31, -1, -1):
        bit = np.uint32(1 << i)
        tp = jnp.where(count_ge(vp, tp | bit) >= kp, tp | bit, tp)
        td = jnp.where(count_ge(vd, td | bit) >= kd, td | bit, td)
    strict_p = vp > tp
    strict_d = vd > td
    bound_p = (vp == tp) & (vp > 0)
    bound_d = (vd == td) & (vd > 0)
    take_p = kp - (strict_p.astype(jnp.float32) @ ones)[:, None]
    take_d = kd - (strict_d.astype(jnp.float32) @ ones)[:, None]
    # boundary tier in index order: search over descending-index weights
    # (distinct per row, so the take-th largest threshold takes exactly
    # `take` pages)
    iv = np.uint32(n) - jnp.arange(n, dtype=jnp.uint32)[None, :]
    wp = jnp.where(bound_p, iv, np.uint32(0))
    wd = jnp.where(bound_d, iv, np.uint32(0))
    sp = jnp.zeros_like(tp)
    sd = jnp.zeros_like(td)
    for i in range(16, -1, -1):
        bit = np.uint32(1 << i)
        sp = jnp.where(count_ge(wp, sp | bit) >= take_p, sp | bit, sp)
        sd = jnp.where(count_ge(wd, sd | bit) >= take_d, sd | bit, sd)
    pm = strict_p | (bound_p & (wp >= sp) & (take_p > 0))
    dm = strict_d | (bound_d & (wd >= sd) & (take_d > 0))
    return pm & (kp > 0), dm & (kd > 0)


# ---------------------------------------------------------------------------
# hotness update (access counting + threshold classification)
# ---------------------------------------------------------------------------

def hotness_update_ref(counts, page_ids, *, cool: bool,
                       hot_threshold: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter-add sampled accesses into per-page counters, optionally halve
    (cooling), and classify.  counts: (P,), page_ids: (N,) (-1 = no sample).
    Returns (new_counts, hot_mask)."""
    valid = page_ids >= 0
    ids = jnp.where(valid, page_ids, 0)
    upd = jnp.zeros_like(counts).at[ids].add(
        valid.astype(counts.dtype))
    new = (counts + upd) * (0.5 if cool else 1.0)
    return new, new >= hot_threshold
