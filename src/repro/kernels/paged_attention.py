"""Pallas TPU paged decode attention over the TieredKVCache HBM pool.

One new token per sequence attends over that sequence's pages, located via a
block table (scalar-prefetched so the BlockSpec index_map can do the
indirection — the pattern TPU paged attention uses instead of GPU
gather-from-global).

Grid = (B * KV_heads, pages_per_seq); page axis innermost/sequential with
online-softmax scratch carried across pages.

BlockSpec tiling:
  q:       (1, G, D)           one head-group row for one sequence
  k/v:     (1, page, D)        one pooled page for one kv head
  out:     (1, G, D)
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, page: int, kv_heads: int):
    bh = pl.program_id(0)
    j = pl.program_id(1)
    b = bh // kv_heads

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (G, D)
    k = k_ref[0].astype(jnp.float32)           # (page, D)
    v = v_ref[0].astype(jnp.float32)
    scale = 1.0 / math.sqrt(q.shape[-1])

    s = jnp.einsum("gd,pd->gp", q * scale, k,
                   preferred_element_type=jnp.float32)
    length = lengths_ref[b]
    page_id = table_ref[b, j]
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)[0]
    valid = (pos < length) & (page_id >= 0)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=-1)
    acc = acc_scr[...] * corr[:, None] + jnp.einsum(
        "gp,pd->gd", p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, lengths,
                    *, interpret: bool = True):
    """q: (B,H,D); k/v_pages: (P,page,KV,D); block_table: (B,ppseq);
    lengths: (B,) -> (B,H,D)."""
    B, H, D = q.shape
    Pn, page, KV, _ = k_pages.shape
    G = H // KV
    ppseq = block_table.shape[1]

    qr = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kr = k_pages.transpose(0, 2, 1, 3).reshape(Pn * KV, page, D)
    vr = v_pages.transpose(0, 2, 1, 3).reshape(Pn * KV, page, D)

    def kv_index(bh, j, table, lengths):
        b = bh // KV
        h = bh % KV
        pid = jnp.maximum(table[b, j], 0)
        return (pid * KV + h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * KV, ppseq),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, j, table, lens: (bh, 0, 0)),
            pl.BlockSpec((1, page, D), kv_index),
            pl.BlockSpec((1, page, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, G, D),
                               lambda bh, j, table, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page=page, kv_heads=KV),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, D), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32), qr, kr, vr)
    return out.reshape(B, KV, G, D).reshape(B, H, D)
