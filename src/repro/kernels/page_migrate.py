"""Pallas TPU page-migration kernel: the tiering engine's datapath.

Executes one migration plan (promote + demote lists) as a single batched
page gather/scatter over the two pools.  The page ids are scalar-prefetched
so the BlockSpec index_maps perform the indirection; each grid step streams
one page (page_elems row) through VMEM.

On a real system the source pool rows live in host memory and arrive via DMA;
here both pools are device arrays and the kernel is the device-side half of
the copy (the host side is jax.device_put with donation, see
core/tiered_kv.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dst_ids, src_ids, src_ref, dst_in_ref, dst_ref):
    i = pl.program_id(0)
    valid = (dst_ids[i] >= 0) & (src_ids[i] >= 0)
    row = jnp.where(valid, src_ref[0], dst_in_ref[0])
    dst_ref[0] = row.astype(dst_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0,))
def page_migrate(dst_pool, src_pool, dst_ids, src_ids, *,
                 interpret: bool = True):
    """dst/src_pool: (P, page_elems); ids: (N,) int32, -1 = no-op.
    Returns the updated dst_pool (buffer donated/aliased)."""
    N = src_ids.shape[0]
    page_elems = dst_pool.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, page_elems),
                         lambda i, d, s: (jnp.maximum(s[i], 0), 0)),
            pl.BlockSpec((1, page_elems),
                         lambda i, d, s: (jnp.maximum(d[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, page_elems),
                               lambda i, d, s: (jnp.maximum(d[i], 0), 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(dst_ids.astype(jnp.int32), src_ids.astype(jnp.int32),
      src_pool, dst_pool)
