"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the compiled Pallas kernels run natively; on CPU (this container,
including the multi-pod dry-run) the same math executes through the pure-jnp
reference implementations, which share the online-softmax block structure —
so tests exercise the kernels in interpret mode against the refs, while
models remain portable.

Set ``FORCE = "pallas" | "ref"`` to pin a path (tests use "pallas" with
interpret mode; the dry-run uses "ref" so the lowered HLO stays analyzable
by cost_analysis).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as R

FORCE: Optional[str] = None


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _use_pallas() -> bool:
    if FORCE == "pallas":
        return True
    if FORCE == "ref":
        return False
    return _on_tpu()


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0):
    if _use_pallas():
        from .flash_attention import flash_attention as fa
        return fa(q, k, v, causal=causal, window=window,
                  logit_softcap=logit_softcap, interpret=not _on_tpu())
    return R.flash_attention_ref(q, k, v, causal=causal, window=window,
                                 logit_softcap=logit_softcap)


def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    logit_softcap: float = 0.0):
    if _use_pallas() and logit_softcap == 0.0:
        from .paged_attention import paged_attention as pa
        return pa(q, k_pages, v_pages, block_table, lengths,
                  interpret=not _on_tpu())
    return R.paged_attention_ref(q, k_pages, v_pages, block_table, lengths,
                                 logit_softcap=logit_softcap)


def select_path() -> str:
    """The dispatch target :func:`select_topk` resolves to right now
    (``"pallas"`` or ``"ref"``).  The compiled epoch loop folds this into
    its jit-cache key so flipping :data:`FORCE` retraces instead of
    silently reusing a function compiled for the other path."""
    return "pallas" if _use_pallas() else "ref"


def select_topk(p_mask, p_heat, d_mask, d_heat, n_promote, n_demote,
                mode: Optional[str] = None):
    """Exact top-k promote/demote selection masks (stable index tie-break,
    bit-exact vs numpy's stable sorts); see ``kernels/select_topk.py``.

    ``mode=None`` resolves via :func:`select_path` (the ``FORCE``/TPU
    dispatch); ``"pallas"``/``"ref"`` pin one implementation — the single
    place the interpret-mode rule lives, so callers (the compiled epoch
    loop in particular) never re-derive it."""
    if mode is None:
        mode = select_path()
    if mode == "pallas":
        from .select_topk import select_topk as sk
        return sk(p_mask, p_heat, d_mask, d_heat, n_promote, n_demote,
                  interpret=not _on_tpu())
    if mode == "ref":
        return R.select_topk_ref(p_mask, p_heat, d_mask, d_heat,
                                 n_promote, n_demote)
    raise ValueError(f"unknown selection mode {mode!r}; "
                     "expected 'pallas', 'ref' or None")


def topk_mask(scores, k, valid=None, mode: Optional[str] = None):
    """Exact top-``k`` boolean mask over a 1-D float32 score vector
    (descending, page/candidate-index tie-break) — the promote side of
    :func:`select_topk` with an empty demote side.

    Used by the BO acquisition's top-q-EI step
    (:func:`repro.core.bo.forest_fast.suggest_topq`) instead of a dense
    ``np.argsort(-ei)``; ``k`` may be a traced scalar so a jitted caller
    does not retrace when the batch's model-slot count changes.
    """
    s = jnp.asarray(scores, jnp.float32)[None, :]
    v = jnp.ones(s.shape, bool) if valid is None \
        else jnp.asarray(valid, bool)[None, :]
    pm, _ = select_topk(v, s, jnp.zeros(s.shape, bool), jnp.zeros_like(s),
                        jnp.asarray([k]), jnp.asarray([0]), mode=mode)
    return pm[0]


def page_migrate(dst_pool, src_pool, dst_ids, src_ids):
    if _use_pallas():
        from .page_migrate import page_migrate as pm
        return pm(dst_pool, src_pool, dst_ids, src_ids,
                  interpret=not _on_tpu())
    return R.page_migrate_ref(dst_pool, src_pool, dst_ids, src_ids)


def hotness_update(counts, page_ids, *, cool: bool, hot_threshold: float):
    return R.hotness_update_ref(counts, page_ids, cool=cool,
                                hot_threshold=hot_threshold)
