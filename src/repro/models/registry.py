"""--arch <id> resolution: config + model functions + input builders."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, get_config, all_arch_ids
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models import transformer as T


def list_archs():
    return all_arch_ids()


def get_model(arch: str, smoke: bool = False):
    cfg = get_config(arch, smoke=smoke)
    return cfg, T


def extra_shape(cfg: ModelConfig, batch: int):
    """Shape of the modality-frontend stub input, if any."""
    if cfg.family == "encdec":
        return (batch, cfg.enc_ctx, cfg.d_model)
    if cfg.family == "vlm":
        return (batch, cfg.n_patches, cfg.vision_dim)
    return None


def make_batch(cfg: ModelConfig, batch: int, seq: int, key=None):
    """Concrete (smoke-test) batch."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab)
    out = {"tokens": tokens, "labels": tokens}
    es = extra_shape(cfg, batch)
    if es is not None:
        out["extra"] = jax.random.normal(k2, es, jnp.float32) * 0.02
    return out


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SWA/hybrid/recurrent)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True
