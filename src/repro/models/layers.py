"""Neural-net layer library (pure JAX, no flax) for the 10 assigned archs.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every init
function returns ``(params, specs)`` where ``specs`` mirrors the params tree
with :class:`jax.sharding.PartitionSpec` leaves using *logical* mesh axis
names ``"data"`` (DP/FSDP) and ``"model"`` (TP/EP); the launcher resolves
them against the physical mesh (adding the ``"pod"`` axis for multi-pod).

Block types: GQA attention (full / sliding-window / alternating local-global,
logit softcap, RoPE incl. partial/"2d"), SwiGLU & GeLU MLPs, top-k MoE with
sort-based dropless dispatch (EP over "model"), RG-LRU (recurrentgemma),
sLSTM / mLSTM (xLSTM), and cross-attention (whisper decoder, llama-vision).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# utilities
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype=jnp.bfloat16):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.uniform(key, (d_in, d_out), jnp.float32,
                               -scale, scale)).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight + bias).astype(dt)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0,
               rotary_dim: Optional[int] = None):
    rd = rotary_dim or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    return inv  # (rd/2,)


def apply_rope(x, positions, theta: float = 10000.0,
               rotary_frac: float = 1.0):
    """x: (..., S, H, D); positions: (..., S).  ``rotary_frac < 1`` rotates
    only the first fraction of dims (chatglm's 2d/partial RoPE)."""
    D = x.shape[-1]
    rd = int(D * rotary_frac)
    rd -= rd % 2
    if rd == 0:
        return x
    inv = rope_freqs(D, theta, rd)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA; causal / sliding-window / cross)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rotary_frac: float = 1.0
    window: int = 0              # 0 = full attention; >0 = sliding window
    logit_softcap: float = 0.0   # 0 = off (gemma2 uses 50.0)
    causal: bool = True
    use_rope: bool = True
    qk_norm: bool = False


def attn_init(key, cfg: AttnCfg, dtype=jnp.bfloat16) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    qd = cfg.n_heads * cfg.head_dim
    kvd = cfg.n_kv_heads * cfg.head_dim
    params = {
        "wq": dense_init(ks[0], cfg.d_model, qd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, kvd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, kvd, dtype),
        "wo": dense_init(ks[3], qd, cfg.d_model, dtype),
    }
    specs = {
        "wq": P("data", "model"), "wk": P("data", "model"),
        "wv": P("data", "model"), "wo": P("model", "data"),
    }
    return params, specs


def _sdpa(q, k, v, *, causal, window, cap, q_pos, k_pos, dtype):
    """q: (B,S,H,D), k/v: (B,T,KV,D) — grouped-query attention core."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, KV, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if cap > 0:
        logits = softcap(logits, cap)
    mask = jnp.ones((S, k.shape[1]), dtype=bool) if not causal else \
        (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, D)


def attn_apply(params: Params, cfg: AttnCfg, x, positions,
               kv_cache: Optional[Tuple] = None,
               cross_kv: Optional[Tuple] = None,
               use_flash: bool = True):
    """Returns (out, new_kv_cache).

    * training/prefill: ``kv_cache=None`` -> full self-attention over x.
    * decode: ``kv_cache=(k_buf, v_buf, length)`` -> append, attend.
    * cross-attention: ``cross_kv=(k, v)`` precomputed from the encoder.
    """
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, D)
    if cross_kv is not None:
        k, v = cross_kv
        T = k.shape[1]
        out = _sdpa(q, k, v, causal=False, window=0, cap=cfg.logit_softcap,
                    q_pos=jnp.arange(S), k_pos=jnp.arange(T), dtype=x.dtype)
        return out.reshape(B, S, H * D) @ params["wo"], None

    k = (x @ params["wk"]).reshape(B, S, KV, D)
    v = (x @ params["wv"]).reshape(B, S, KV, D)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_frac)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_frac)

    if kv_cache is None:
        if use_flash and S >= 512 and S * B <= (1 << 22):
            from repro.kernels import ops as kops
            out = kops.flash_attention(
                q, k, v, causal=cfg.causal, window=cfg.window,
                logit_softcap=cfg.logit_softcap)
        else:
            out = _sdpa(q, k, v, causal=cfg.causal, window=cfg.window,
                        cap=cfg.logit_softcap, q_pos=positions[0],
                        k_pos=positions[0], dtype=x.dtype)
        return out.reshape(B, S, H * D) @ params["wo"], None

    # ---- decode: append to cache then attend over it ----
    # Sliding-window layers use the buffer as a ring (T == window): softmax
    # is permutation-invariant and keys carry their RoPE phase from write
    # time, so slot order does not matter.
    k_buf, v_buf, length = kv_cache
    T = k_buf.shape[1]
    idx = length % T
    k_buf = jax.lax.dynamic_update_slice(k_buf, k.astype(k_buf.dtype),
                                         (0, idx, 0, 0))
    v_buf = jax.lax.dynamic_update_slice(v_buf, v.astype(v_buf.dtype),
                                         (0, idx, 0, 0))
    k_pos = jnp.arange(T)
    valid = (k_pos <= length) | (length >= T)
    if cfg.window > 0 and T > cfg.window:
        valid = valid & (k_pos > length - cfg.window)
    qg = q.reshape(B, S, KV, H // KV, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_buf).astype(jnp.float32)
    logits *= 1.0 / math.sqrt(D)
    if cfg.logit_softcap > 0:
        logits = softcap(logits, cfg.logit_softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_buf).reshape(B, S, H * D)
    return out @ params["wo"], (k_buf, v_buf, length + S)


def kv_cache_init(cfg: AttnCfg, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
            jnp.zeros((), jnp.int32))


def kv_cache_specs(decode_seq_shard: bool = True):
    """KV buffers: batch over data, cached sequence over model (distributed
    flash-decode: partial softmax terms are combined by XLA collectives)."""
    seq = "model" if decode_seq_shard else None
    return (P("data", seq, None, None), P("data", seq, None, None), P())


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, kind: str = "swiglu", dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        params = {"w_gate": dense_init(ks[0], d_model, d_ff, dtype),
                  "w_up": dense_init(ks[1], d_model, d_ff, dtype),
                  "w_down": dense_init(ks[2], d_ff, d_model, dtype)}
        specs = {"w_gate": P("data", "model"), "w_up": P("data", "model"),
                 "w_down": P("model", "data")}
    else:  # gelu
        params = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
                  "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
        specs = {"w_up": P("data", "model"), "w_down": P("model", "data")}
    return params, specs


def mlp_apply(params: Params, x, kind: str = "swiglu"):
    if kind == "swiglu":
        return (jax.nn.silu(x @ params["w_gate"]) *
                (x @ params["w_up"])) @ params["w_down"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ params["w_gate"], approximate=True) *
                (x @ params["w_up"])) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"], approximate=True) @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts — top-k, sort-based dropless-ish dispatch, EP over
# "model".  Expert tensors: (E, d_model, d_ff) with E sharded.
# ---------------------------------------------------------------------------

def moe_init(key, d_model, d_ff, n_experts, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    def einit(k, shape, fan_in):
        scale = 1.0 / math.sqrt(fan_in)
        return jax.random.uniform(k, shape, jnp.float32, -scale,
                                  scale).astype(dtype)
    params = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": einit(ks[1], (n_experts, d_model, d_ff), d_model),
        "w_up": einit(ks[2], (n_experts, d_model, d_ff), d_model),
        "w_down": einit(ks[3], (n_experts, d_ff, d_model), d_ff),
    }
    specs = {
        "router": P("data", None),
        "w_gate": P("model", "data", None),
        "w_up": P("model", "data", None),
        "w_down": P("model", None, "data"),
    }
    return params, specs


#: perf iteration #3 (EXPERIMENTS.md §Perf): constrain the (E, C, D) expert
#: buffers to also shard C over the DP axis so the dispatch scatter lowers
#: to reduce-scatter instead of a full all-reduce of the buffer.
MOE_BUFFER_SPEC = None


def set_moe_buffer_sharding(spec):
    global MOE_BUFFER_SPEC
    MOE_BUFFER_SPEC = spec


def moe_apply(params: Params, x, n_experts: int, top_k: int,
              capacity_factor: float = 1.25):
    """x: (B, S, D) -> (B, S, D), plus aux load-balancing loss."""
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    logits = (xf.astype(jnp.float32) @ params["router"])   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- dispatch: sort token-slots by expert, take first C per expert ---
    # small token counts (decode steps, smoke tests) run dropless; large
    # training microbatches use GShard-style capacity
    if T * top_k <= 4096:
        C = T * top_k
    else:
        C = max(top_k, int(T * top_k * capacity_factor / n_experts))
    slot_expert = gate_idx.reshape(-1)                       # (T*k,)
    order = jnp.argsort(slot_expert)                         # stable
    sorted_expert = slot_expert[order]
    # position of each sorted slot within its expert
    same = jnp.cumsum(
        jax.nn.one_hot(sorted_expert, n_experts, dtype=jnp.int32), axis=0)
    pos_sorted = same[jnp.arange(T * top_k), sorted_expert] - 1
    keep = pos_sorted < C
    token_sorted = order // top_k

    # scatter tokens into (E, C, D) buffers
    buf = jnp.zeros((n_experts, C, D), x.dtype)
    e_idx = jnp.where(keep, sorted_expert, 0)
    c_idx = jnp.where(keep, pos_sorted, 0)
    contrib = jnp.where(keep[:, None], xf[token_sorted], 0.0)
    buf = buf.at[e_idx, c_idx].add(contrib.astype(x.dtype))
    if MOE_BUFFER_SPEC is not None and C % 8 == 0:
        buf = jax.lax.with_sharding_constraint(buf, MOE_BUFFER_SPEC)

    # expert computation (EP: E sharded over "model")
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, D)

    # combine: gather back per slot, weight by gate value
    slot_out = out_buf[e_idx, c_idx]                          # (T*k, D)
    slot_out = jnp.where(keep[:, None], slot_out, 0.0)
    gate_sorted = gate_vals.reshape(-1)[order]
    weighted = slot_out * gate_sorted[:, None].astype(slot_out.dtype)
    y = jnp.zeros((T, D), x.dtype).at[token_sorted].add(
        weighted.astype(x.dtype))

    # aux loss (Switch-style load balancing)
    density = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], n_experts), axis=0)
    router_mean = probs.mean(0)
    aux = n_experts * jnp.sum(density * router_mean)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma) — gated linear recurrence via associative scan
# ---------------------------------------------------------------------------

def rglru_init(key, d_model, d_rnn, n_heads, conv_width=4, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    params = {
        "w_x": dense_init(ks[0], d_model, d_rnn, dtype),
        "w_y": dense_init(ks[1], d_model, d_rnn, dtype),
        "conv_w": (jax.random.normal(ks[2], (conv_width, d_rnn),
                                     jnp.float32) * 0.02).astype(dtype),
        "w_gate_a": dense_init(ks[3], d_rnn, d_rnn, dtype),
        "w_gate_x": dense_init(ks[4], d_rnn, d_rnn, dtype),
        "lambda_p": jnp.linspace(4.0, 9.0, d_rnn, dtype=jnp.float32),
        "w_out": dense_init(ks[5], d_rnn, d_model, dtype),
    }
    specs = {"w_x": P("data", "model"), "w_y": P("data", "model"),
             "conv_w": P(None, "model"),
             "w_gate_a": P("data", "model"), "w_gate_x": P("data", "model"),
             "lambda_p": P("model"), "w_out": P("model", "data")}
    return params, specs


def _rglru_core(params, u, h0=None):
    """u: (B, S, R) pre-activation; returns (y, h_last)."""
    B, S, R = u.shape
    r = jax.nn.sigmoid((u @ params["w_gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_gate_x"]).astype(jnp.float32))
    c = 8.0
    log_a = -c * r * jax.nn.softplus(params["lambda_p"])       # (B,S,R)
    a = jnp.exp(log_a)
    gated_x = u.astype(jnp.float32) * i * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))

    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, gated_x), axis=1)
    h = aa * h0[:, None, :] + bb
    return h.astype(u.dtype), h[:, -1, :]


def rglru_apply(params, x, state=None):
    """x: (B,S,D).  state: (conv_tail (B,W-1,R), h (B,R)) for decode."""
    u = x @ params["w_x"]
    gate_y = jax.nn.gelu(x @ params["w_y"], approximate=True)
    W = params["conv_w"].shape[0]
    if state is None:
        conv_tail = jnp.zeros((x.shape[0], W - 1, u.shape[-1]), u.dtype)
        h0 = None
    else:
        conv_tail, h_prev = state
        h0 = h_prev
    upad = jnp.concatenate([conv_tail, u], axis=1)
    # short depthwise causal conv
    uc = sum(upad[:, i:i + u.shape[1]] * params["conv_w"][i]
             for i in range(W))
    y, h_last = _rglru_core(params, uc, h0)
    out = (y * gate_y) @ params["w_out"]
    new_tail = upad[:, -(W - 1):] if W > 1 else conv_tail
    return out, (new_tail, h_last)


def rglru_state_init(batch, d_rnn, conv_width=4, dtype=jnp.bfloat16):
    return (jnp.zeros((batch, conv_width - 1, d_rnn), dtype),
            jnp.zeros((batch, d_rnn), jnp.float32))


# ---------------------------------------------------------------------------
# xLSTM blocks — mLSTM (matrix memory, chunked linear-attention form) and
# sLSTM (scalar memory, sequential scan).
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model, n_heads, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    d_inner = 2 * d_model
    params = {
        "w_up": dense_init(ks[0], d_model, d_inner, dtype),
        "w_q": dense_init(ks[1], d_model, d_model, dtype),
        "w_k": dense_init(ks[2], d_model, d_model, dtype),
        "w_v": dense_init(ks[3], d_model, d_inner, dtype),
        "w_if": dense_init(ks[4], d_model, 2 * n_heads, jnp.float32),
        "w_down": dense_init(ks[5], d_inner, d_model, dtype),
    }
    specs = {"w_up": P("data", "model"), "w_q": P("data", "model"),
             "w_k": P("data", "model"), "w_v": P("data", "model"),
             "w_if": P("data", None), "w_down": P("model", "data")}
    return params, specs


def mlstm_apply(params, x, n_heads: int, state=None, chunk: int = 256):
    """Chunkwise-parallel mLSTM: within-chunk quadratic + cross-chunk
    recurrent matrix state (C, n) per head — the TPU-friendly formulation.
    q/k are d_model-wide, v/output d_inner-wide (xLSTM block shape)."""
    B, S, D = x.shape
    u = x @ params["w_up"]
    di = u.shape[-1]
    H = n_heads
    hd = D // H          # q/k head dim
    hv = di // H         # v head dim
    q = (x @ params["w_q"]).reshape(B, S, H, hd) / math.sqrt(hd)
    k = (x @ params["w_k"]).reshape(B, S, H, hd) / math.sqrt(hd)
    v = (x @ params["w_v"]).reshape(B, S, H, hv)
    gates = (x.astype(jnp.float32) @ params["w_if"]).reshape(B, S, H, 2)
    log_f = -jax.nn.softplus(-gates[..., 0])     # forget gate in log space
    log_i = gates[..., 1]                        # input gate (exp gating)

    if S % chunk != 0:
        chunk = S  # decode / small sequences
    nC = S // chunk
    qc = q.reshape(B, nC, chunk, H, hd)
    kc = k.reshape(B, nC, chunk, H, hd)
    vc = v.reshape(B, nC, chunk, H, hv)
    lf = log_f.reshape(B, nC, chunk, H)
    li = log_i.reshape(B, nC, chunk, H)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hv), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        C0, n0 = state

    def step(carry, blk):
        C, n = carry
        qb, kb, vb, lfb, lib = blk          # (B, chunk, H, ...)
        cs_f = jnp.cumsum(lfb, axis=1)      # (B, c, H)
        total_f = cs_f[:, -1]
        # decay from chunk start to position t (inclusive of gates)
        dec_in = jnp.exp(cs_f)[..., None]
        # intra-chunk attention with relative decay
        g = cs_f[:, :, None, :] - cs_f[:, None, :, :] + lib[:, None, :, :]
        mask = jnp.tril(jnp.ones((qb.shape[1], qb.shape[1]), bool))
        g = jnp.where(mask[None, :, :, None], g, -jnp.inf)
        w = jnp.exp(jnp.minimum(g, 0.0))    # stabilized
        scores = jnp.einsum("bthd,bshd->btsh", qb.astype(jnp.float32),
                            kb.astype(jnp.float32))
        intra = jnp.einsum("btsh,bshd->bthd", scores * w,
                           vb.astype(jnp.float32))
        nor_i = jnp.einsum("btsh,bsh->bth", scores * w,
                           jnp.ones(kb.shape[:3]))
        # inter-chunk from carried state
        inter = jnp.einsum("bthd,bhde->bthe", qb.astype(jnp.float32) * dec_in,
                           C)
        nor_c = jnp.einsum("bthd,bhd->bth", qb.astype(jnp.float32) * dec_in, n)
        nor = jnp.maximum(jnp.abs(nor_i + nor_c), 1.0)
        out = (intra + inter) / nor[..., None]
        # update carried state
        dec_out = jnp.exp(total_f[:, None, :] - cs_f + lib)  # (B,c,H)
        kv = jnp.einsum("bshd,bsh,bshe->bhde", kb.astype(jnp.float32),
                        dec_out, vb.astype(jnp.float32))
        ksum = jnp.einsum("bshd,bsh->bhd", kb.astype(jnp.float32), dec_out)
        C = C * jnp.exp(total_f)[..., None, None] + kv
        n = n * jnp.exp(total_f)[..., None] + ksum
        return (C, n), out

    blks = (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
            lf.swapaxes(0, 1), li.swapaxes(0, 1))
    (Cf, nf), outs = jax.lax.scan(step, (C0, n0), blks)
    y = outs.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(u)
    return y @ params["w_down"], (Cf, nf)


def mlstm_state_init(batch, d_model, n_heads):
    hd = d_model // n_heads        # q/k head dim
    hv = 2 * d_model // n_heads    # v head dim
    return (jnp.zeros((batch, n_heads, hd, hv), jnp.float32),
            jnp.zeros((batch, n_heads, hd), jnp.float32))


def slstm_init(key, d_model, n_heads, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    params = {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype),
        "r_in": dense_init(ks[1], d_model, 4 * d_model, dtype),
        "w_down": dense_init(ks[2], d_model, d_model, dtype),
        "norm": jnp.zeros((d_model,), jnp.float32),
    }
    specs = {"w_in": P("data", "model"), "r_in": P("data", "model"),
             "w_down": P("data", "model"), "norm": P(None)}
    return params, specs


def slstm_apply(params, x, state=None, unroll: int = 8):
    """sLSTM: true sequential recurrence (scalar memories, exp gating)."""
    B, S, D = x.shape
    zi = x @ params["w_in"]                       # (B, S, 4D)
    if state is None:
        h0 = jnp.zeros((B, D), jnp.float32)
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.ones((B, D), jnp.float32)
        m0 = jnp.zeros((B, D), jnp.float32)
    else:
        h0, c0, n0, m0 = state

    r_in = params["r_in"].astype(jnp.float32)

    def step(carry, zt):
        h, c, n, m = carry
        pre = zt.astype(jnp.float32) + h @ r_in   # (B, 4D)
        z, i, f, o = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        log_f = -jax.nn.softplus(-f)              # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, i)
        ig = jnp.exp(i - m_new)
        fg = jnp.exp(log_f + m - m_new)
        c = fg * c + ig * z
        n = fg * n + ig
        h = o * (c / jnp.maximum(n, 1.0))
        return (h, c, n, m_new), h

    (hf, cf, nf, mf), hs = jax.lax.scan(step, (h0, c0, n0, m0),
                                        zi.swapaxes(0, 1), unroll=unroll)
    y = hs.swapaxes(0, 1).astype(x.dtype)
    y = rms_norm(y, params["norm"])
    return y @ params["w_down"], (hf, cf, nf, mf)


def slstm_state_init(batch, d_model):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return (z, z, jnp.ones_like(z), z)
