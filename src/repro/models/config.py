"""ModelConfig: one dataclass covering all 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                    # "lm" | "encdec" | "vlm"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    act: str = "swiglu"            # "swiglu" | "gelu"
    norm: str = "rms"              # "rms" | "ln"
    rope_theta: float = 10000.0
    rotary_frac: float = 1.0       # chatglm3: 0.5 ("2d" partial rotary)
    window: int = 0                # sliding-window width for local layers
    layer_pattern: Tuple[str, ...] = ()   # per-layer block kinds
    moe_experts: int = 0
    moe_top_k: int = 0
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    tie_embeddings: bool = True
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_ctx: int = 1500            # audio frames after the conv frontend stub
    # vision (llama-3.2-vision)
    cross_attn_every: int = 0      # insert cross-attn each k-th layer
    n_patches: int = 1601
    vision_dim: int = 1280
    # numerics
    dtype: str = "bfloat16"
    remat: bool = True
    # training-shape scan/microbatching knob (see train.step)
    microbatch: int = 0            # 0 = auto
    # whether long-context decode is sub-quadratic (SWA/recurrent)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding tables are padded to a multiple of 256 so the vocab dim
        divides the 16-way 'model' axis (standard vocab padding)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern:
            assert len(self.layer_pattern) == self.n_layers
            return self.layer_pattern
        return tuple(["attn"] * self.n_layers)

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def param_count(self) -> int:
        """Analytic parameter count (dense equivalents; for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.pattern:
            if kind.startswith("attn"):
                total += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            elif kind == "rglru":
                r = int(d * 1.5)
                total += 2 * d * r + 2 * r * r + r * d
            elif kind == "mlstm":
                di = 2 * d
                total += d * di + 2 * d * d + d * di + di * d
            elif kind == "slstm":
                total += 8 * d * d + d * d
            if self.d_ff > 0 and kind.startswith("attn"):
                n_mats = 3 if self.act in ("swiglu", "geglu") else 2
                if self.moe_experts:
                    total += self.moe_experts * n_mats * d * self.d_ff \
                        + d * self.moe_experts
                else:
                    total += n_mats * d * self.d_ff
        if self.family == "encdec":
            # encoder layers (self-attn + mlp) + decoder cross-attn
            per_enc = 4 * d * d + 2 * d * self.d_ff
            total += self.enc_layers * per_enc + self.n_layers * 4 * d * d
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (4 * d * self.n_heads * self.hd) \
                + self.vision_dim * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of experts)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        n_mats = 3 if self.act == "swiglu" else 2
        dense = self.param_count() - sum(
            self.moe_experts * n_mats * d * self.d_ff
            for k in self.pattern if k.startswith("attn"))
        active_moe = sum(self.moe_top_k * n_mats * d * self.d_ff
                         for k in self.pattern if k.startswith("attn"))
        return dense + active_moe


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (arch x shape grid)."""
    name: str                      # train_4k / prefill_32k / decode_32k / long_500k
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
