"""The unified model: decoder-only LMs, whisper-style encoder-decoder and
llama-3.2-vision cross-attention variants, assembled per ModelConfig.

Layers are STACKED along the repeating pattern period and executed with
``jax.lax.scan`` — compile time is depth-independent (61-layer kimi-k2
compiles as fast as a 2-layer smoke config), which is what makes the
40-cell x 2-mesh dry-run tractable and is how a production framework keeps
XLA programs small.

Param layout: params["blocks"][k] for offset k in the pattern period, each a
pytree stacked over n_groups = n_layers / period.

API (pure functions over param pytrees):
  init(key, cfg)                       -> (params, specs)
  shape_init(key, cfg)                 -> (ShapeDtypeStructs, specs)
  forward / hidden_forward             -> logits / hidden   (train, prefill)
  loss_fn(params, cfg, batch)          -> scalar loss
  decode_init(cfg, batch, max_len)     -> (cache, cache_specs)
  prime_cross_kv(params, cfg, cache, extra) -> cache
  decode_step(params, cfg, tokens, pos, cache) -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from .config import ModelConfig

# ---------------------------------------------------------------------------
# pattern periodicity
# ---------------------------------------------------------------------------

def _cross_layers(cfg: ModelConfig):
    if cfg.family == "encdec":
        return set(range(cfg.n_layers))          # every decoder layer
    if cfg.family == "vlm" and cfg.cross_attn_every:
        return set(range(cfg.cross_attn_every - 1, cfg.n_layers,
                         cfg.cross_attn_every))
    return set()


def pattern_period(cfg: ModelConfig) -> int:
    """Smallest period of (block kind, has-cross) over the layer stack."""
    pat = cfg.pattern
    cross = _cross_layers(cfg)
    n = cfg.n_layers
    for p in range(1, n + 1):
        if n % p:
            continue
        if all(pat[i] == pat[i % p] and ((i in cross) == ((i % p) in cross))
               for i in range(n)):
            return p
    return n


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_specs(spec):
    return jax.tree.map(lambda s: P(None, *tuple(s)), spec,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig, kind: str) -> L.AttnCfg:
    local = kind == "attn_local"
    return L.AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta,
        rotary_frac=cfg.rotary_frac,
        window=cfg.window if local or (cfg.window and kind == "attn") else 0,
        logit_softcap=cfg.attn_softcap, causal=True)


def _norm_init(cfg: ModelConfig, d: int):
    if cfg.norm == "rms":
        return jnp.zeros((d,), jnp.float32), P(None)
    return {"w": jnp.ones((d,), jnp.float32),
            "b": jnp.zeros((d,), jnp.float32)}, {"w": P(None), "b": P(None)}


def _apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rms":
        return L.rms_norm(x, p)
    return L.layer_norm(x, p["w"], p["b"])


def _d_rnn(cfg: ModelConfig) -> int:
    return int(cfg.d_model * 1.5)


def init_layer(key, cfg: ModelConfig, kind: str, cross: bool):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["norm1"], s["norm1"] = _norm_init(cfg, cfg.d_model)
    if kind.startswith("attn"):
        p["attn"], s["attn"] = L.attn_init(ks[0], _attn_cfg(cfg, kind),
                                           cfg.jdtype)
    elif kind == "rglru":
        p["rnn"], s["rnn"] = L.rglru_init(ks[0], cfg.d_model, _d_rnn(cfg),
                                          cfg.n_heads, dtype=cfg.jdtype)
    elif kind == "mlstm":
        p["rnn"], s["rnn"] = L.mlstm_init(ks[0], cfg.d_model, cfg.n_heads,
                                          cfg.jdtype)
    elif kind == "slstm":
        p["rnn"], s["rnn"] = L.slstm_init(ks[0], cfg.d_model, cfg.n_heads,
                                          cfg.jdtype)
    if cross:
        p["norm_x"], s["norm_x"] = _norm_init(cfg, cfg.d_model)
        p["cross"], s["cross"] = L.attn_init(ks[1], _attn_cfg(cfg, "attn"),
                                             cfg.jdtype)
        p["gate_x"] = jnp.zeros((), jnp.float32)
        s["gate_x"] = P()
    if cfg.d_ff > 0 and kind.startswith("attn"):
        p["norm2"], s["norm2"] = _norm_init(cfg, cfg.d_model)
        if cfg.moe_experts:
            p["moe"], s["moe"] = L.moe_init(ks[2], cfg.d_model, cfg.d_ff,
                                            cfg.moe_experts, cfg.jdtype)
        else:
            p["mlp"], s["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                            cfg.act, cfg.jdtype)
    return p, s


def init(key, cfg: ModelConfig):
    period = pattern_period(cfg)
    n_groups = cfg.n_layers // period
    cross_set = _cross_layers(cfg)
    keys = jax.random.split(key, cfg.n_layers + cfg.enc_layers + 4)

    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"] = L.dense_init(keys[0], cfg.padded_vocab, cfg.d_model,
                                   cfg.jdtype)
    specs["embed"] = P("model", "data")
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(keys[1], cfg.d_model,
                                         cfg.padded_vocab, cfg.jdtype)
        specs["unembed"] = P("data", "model")
    params["norm_f"], specs["norm_f"] = _norm_init(cfg, cfg.d_model)

    blocks, bspecs = [], []
    for k in range(period):
        per_group = []
        spec_k = None
        for g in range(n_groups):
            i = g * period + k
            p, s = init_layer(keys[2 + i], cfg, cfg.pattern[k],
                              k in cross_set)
            per_group.append(p)
            spec_k = s
        blocks.append(_stack_trees(per_group))
        bspecs.append(_stack_specs(spec_k))
    params["blocks"] = blocks
    specs["blocks"] = bspecs

    if cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, family="lm", moe_experts=0)
        per_group, spec_e = [], None
        for i in range(cfg.enc_layers):
            p, s = init_layer(keys[2 + cfg.n_layers + i], enc_cfg, "attn",
                              cross=False)
            per_group.append(p)
            spec_e = s
        params["encoder"] = _stack_trees(per_group)
        specs["encoder"] = _stack_specs(spec_e)
        params["enc_norm_f"], specs["enc_norm_f"] = _norm_init(cfg,
                                                               cfg.d_model)
    if cfg.family == "vlm":
        params["vision_proj"] = L.dense_init(keys[-1], cfg.vision_dim,
                                             cfg.d_model, cfg.jdtype)
        specs["vision_proj"] = P(None, "data")
    return params, specs


def shape_init(key, cfg: ModelConfig):
    """(param ShapeDtypeStructs, PartitionSpecs) — no allocation."""
    cap = []

    def f(k):
        p, s = init(k, cfg)
        cap.append(s)
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, cap[0]


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _layer_forward(p, cfg: ModelConfig, kind: str, x, positions,
                   memory, use_flash=True):
    aux = 0.0
    h = _apply_norm(cfg, p["norm1"], x)
    if kind.startswith("attn"):
        acfg = _attn_cfg(cfg, kind)
        out, _ = L.attn_apply(p["attn"], acfg, h, positions,
                              use_flash=use_flash)
    elif kind == "rglru":
        out, _ = L.rglru_apply(p["rnn"], h)
    elif kind == "mlstm":
        out, _ = L.mlstm_apply(p["rnn"], h, cfg.n_heads)
    elif kind == "slstm":
        out, _ = L.slstm_apply(p["rnn"], h)
    else:
        raise ValueError(kind)
    x = x + out
    if "cross" in p and memory is not None:
        hx = _apply_norm(cfg, p["norm_x"], x)
        ckv = _make_cross_kv(cfg, p, memory)
        cout, _ = L.attn_apply(p["cross"], _attn_cfg(cfg, "attn"), hx,
                               positions, cross_kv=ckv)
        x = x + jnp.tanh(p["gate_x"]).astype(x.dtype) * cout
    if "norm2" in p:
        h2 = _apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            out2, aux = L.moe_apply(p["moe"], h2, cfg.moe_experts,
                                    cfg.moe_top_k)
        else:
            out2 = L.mlp_apply(p["mlp"], h2, cfg.act)
        x = x + out2
    return x, aux


def _make_cross_kv(cfg: ModelConfig, p_layer, memory):
    B, T, _ = memory.shape
    k = (memory @ p_layer["cross"]["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    v = (memory @ p_layer["cross"]["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    return k, v


def _encode(params, cfg: ModelConfig, enc_input):
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    x = enc_input.astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    acfg = L.AttnCfg(d_model=cfg.d_model, n_heads=cfg.n_heads,
                     n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                     causal=False, use_rope=False)

    def body(x, p):
        h = _apply_norm(cfg, p["norm1"], x)
        out, _ = L.attn_apply(p["attn"], acfg, h, positions, use_flash=False)
        x = x + out
        h2 = _apply_norm(cfg, p["norm2"], x)
        x = x + L.mlp_apply(p["mlp"], h2, cfg.act)
        return x, 0.0

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return _apply_norm(cfg, params["enc_norm_f"], x)


def _memory(params, cfg: ModelConfig, extra):
    if cfg.family == "encdec":
        return _encode(params, cfg, extra)
    if cfg.family == "vlm":
        return extra.astype(cfg.jdtype) @ params["vision_proj"]
    return None


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

#: sequence-parallelism switch (perf iteration #1, EXPERIMENTS.md §Perf):
#: when set to a PartitionSpec like P("data", "model", None), the residual
#: stream between blocks is constrained to be sequence-sharded over the TP
#: axis, converting the two per-layer TP activation all-reduces into
#: reduce-scatter + all-gather pairs (half the collective bytes) and storing
#: activations sharded.  Set via set_activation_sharding().
ACTIVATION_SPEC: Optional[P] = None


def set_activation_sharding(spec: Optional[P]):
    global ACTIVATION_SPEC
    ACTIVATION_SPEC = spec


#: perf iteration #6 (REFUTED, see EXPERIMENTS.md §Perf): gathering the
#: unembed weight over the FSDP axis traded 2.1 GB of fp32 logit all-reduce
#: for 5.9 GB of weight all-gather under XLA's chosen schedule — off by
#: default, kept for the measurement.
ACTIVATION_AWARE_LOSS = False


def _constrain(x):
    if ACTIVATION_SPEC is not None and x.ndim == 3 and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(x, ACTIVATION_SPEC)
    return x


def hidden_forward(params, cfg: ModelConfig, tokens, extra=None,
                   use_flash: bool = True):
    """Embed -> scan(layer groups) -> final norm.  Returns (hidden, aux)."""
    B, S = tokens.shape
    period = pattern_period(cfg)
    x = params["embed"][tokens] * (math.sqrt(cfg.d_model)
                                   if cfg.norm == "rms" else 1.0)
    x = x.astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    memory = _memory(params, cfg, extra)

    def group_body(carry, gp):
        x, aux = carry
        for k in range(period):
            x = _constrain(x)
            x, a = _layer_forward(gp[k], cfg, cfg.pattern[k], x, positions,
                                  memory, use_flash)
            aux = aux + a
        return (_constrain(x), aux), None

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), tuple(params["blocks"]))
    return _apply_norm(cfg, params["norm_f"], x), aux


def forward(params, cfg: ModelConfig, tokens, extra=None,
            use_flash: bool = True):
    x, aux = hidden_forward(params, cfg, tokens, extra, use_flash)
    unembed = params.get("unembed")
    logits = x @ (unembed if unembed is not None else params["embed"].T)
    if cfg.final_softcap > 0:
        logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, use_flash: bool = True,
            seq_chunk: int = 2048):
    """Next-token loss.  For large S x vocab the unembed+softmax is chunked
    over the sequence so the fp32 logits never materialize in full."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    extra = batch.get("extra")
    B, S = tokens.shape
    x, aux = hidden_forward(params, cfg, tokens, extra, use_flash)
    unembed = params.get("unembed")
    W = unembed if unembed is not None else params["embed"].T

    def chunk_loss(x_c, labels_c):
        # gather the unembed shard over the FSDP axis once per chunk (bf16,
        # vocab stays model-sharded) instead of letting SPMD partial-sum the
        # d-contraction and all-reduce fp32 logits (perf iteration #6)
        Wg = jax.lax.with_sharding_constraint(W, P(None, "model")) \
            if ACTIVATION_AWARE_LOSS else W
        logits = x_c @ Wg
        if cfg.final_softcap > 0:
            logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
        logits = logits.astype(jnp.float32)
        # label logit via one-hot contraction rather than take_along_axis:
        # over the model-sharded vocab axis this lowers to a local masked
        # reduction + a tiny (B,S) all-reduce instead of an all-reduce of the
        # full fp32 logits (perf iteration #5, EXPERIMENTS.md §Perf)
        lbl = jnp.maximum(labels_c, 0)
        onehot = jax.nn.one_hot(lbl, logits.shape[-1], dtype=logits.dtype)
        label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = label_logit - lse
        mask = (labels_c >= 0).astype(jnp.float32)
        return -(ll * mask).sum(), mask.sum()

    if S > seq_chunk and S % seq_chunk == 0:
        n = S // seq_chunk
        xc = x.reshape(B, n, seq_chunk, -1).swapaxes(0, 1)
        lc = labels.reshape(B, n, seq_chunk).swapaxes(0, 1)

        def body(carry, inp):
            tot, cnt = carry
            t, c = chunk_loss(*inp)
            return (tot + t, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (xc, lc))
    else:
        tot, cnt = chunk_loss(x, labels)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _entry_init(cfg: ModelConfig, kind: str, has_cross: bool, batch: int,
                max_len: int):
    entry, espec = {}, {}
    if kind.startswith("attn"):
        acfg = _attn_cfg(cfg, kind)
        eff = min(max_len, cfg.window) if acfg.window else max_len
        entry["kv"] = L.kv_cache_init(acfg, batch, eff, cfg.jdtype)
        espec["kv"] = L.kv_cache_specs()
    elif kind == "rglru":
        entry["state"] = L.rglru_state_init(batch, _d_rnn(cfg),
                                            dtype=cfg.jdtype)
        espec["state"] = (P("data", None, "model"), P("data", "model"))
    elif kind == "mlstm":
        # matrix memory (B, H, hd, hd): H is small (4), so shard the first
        # memory dim over "model" instead of the head dim
        entry["state"] = L.mlstm_state_init(batch, cfg.d_model, cfg.n_heads)
        espec["state"] = (P("data", None, "model", None),
                          P("data", None, "model"))
    elif kind == "slstm":
        entry["state"] = L.slstm_state_init(batch, cfg.d_model)
        espec["state"] = tuple([P("data", "model")] * 4)
    if has_cross:
        shape = (batch, cfg.enc_ctx if cfg.family == "encdec"
                 else cfg.n_patches, cfg.n_kv_heads, cfg.hd)
        entry["cross_kv"] = (jnp.zeros(shape, cfg.jdtype),
                             jnp.zeros(shape, cfg.jdtype))
        espec["cross_kv"] = (P("data", None, None, None),
                             P("data", None, None, None))
    return entry, espec


def decode_init(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree stacked per pattern offset: cache[k] has leading
    n_groups dim.  Returns (cache, PartitionSpecs)."""
    period = pattern_period(cfg)
    n_groups = cfg.n_layers // period
    cross_set = _cross_layers(cfg)
    cache, specs = [], []
    for k in range(period):
        entry, espec = _entry_init(cfg, cfg.pattern[k], k in cross_set,
                                   batch, max_len)
        cache.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), entry))
        specs.append(_stack_specs(espec))
    return cache, specs


def prime_cross_kv(params, cfg: ModelConfig, cache, extra):
    """Fill cross-attention K/V into the decode cache (prefill-time)."""
    memory = _memory(params, cfg, extra)
    if memory is None:
        return cache
    period = pattern_period(cfg)
    cross_set = _cross_layers(cfg)
    for k in range(period):
        if k not in cross_set:
            continue
        gp = params["blocks"][k]

        def per_group(p):
            return _make_cross_kv(cfg, p, memory)
        kv = jax.vmap(per_group, in_axes=0)(gp)   # (n_groups, B, T, KV, D)
        cache[k] = dict(cache[k])
        cache[k]["cross_kv"] = kv
    return cache


def _layer_decode(p, cfg: ModelConfig, kind: str, x, positions, entry):
    entry = dict(entry)
    h = _apply_norm(cfg, p["norm1"], x)
    if kind.startswith("attn"):
        acfg = _attn_cfg(cfg, kind)
        out, entry["kv"] = L.attn_apply(p["attn"], acfg, h, positions,
                                        kv_cache=entry["kv"])
    elif kind == "rglru":
        out, entry["state"] = L.rglru_apply(p["rnn"], h, entry["state"])
    elif kind == "mlstm":
        out, entry["state"] = L.mlstm_apply(p["rnn"], h, cfg.n_heads,
                                            entry["state"])
    elif kind == "slstm":
        out, entry["state"] = L.slstm_apply(p["rnn"], h, entry["state"])
    x = x + out
    if "cross" in p and "cross_kv" in entry:
        hx = _apply_norm(cfg, p["norm_x"], x)
        cout, _ = L.attn_apply(p["cross"], _attn_cfg(cfg, "attn"), hx,
                               positions, cross_kv=entry["cross_kv"])
        x = x + jnp.tanh(p["gate_x"]).astype(x.dtype) * cout
    if "norm2" in p:
        h2 = _apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            out2, _ = L.moe_apply(p["moe"], h2, cfg.moe_experts,
                                  cfg.moe_top_k)
        else:
            out2 = L.mlp_apply(p["mlp"], h2, cfg.act)
        x = x + out2
    return x, entry


def decode_step(params, cfg: ModelConfig, tokens, position, cache):
    """tokens: (B, 1); position: scalar index.  Returns (logits, cache)."""
    B, S = tokens.shape
    period = pattern_period(cfg)
    x = params["embed"][tokens] * (math.sqrt(cfg.d_model)
                                   if cfg.norm == "rms" else 1.0)
    x = x.astype(cfg.jdtype)
    positions = jnp.broadcast_to(position[None], (B, S)) \
        if jnp.ndim(position) == 0 else position

    def group_body(x, scans):
        new_entries = []
        for k in range(period):
            p, entry = scans[k]
            x, ne = _layer_decode(p, cfg, cfg.pattern[k], x, positions, entry)
            new_entries.append(ne)
        return x, tuple(new_entries)

    scans = tuple((params["blocks"][k], cache[k]) for k in range(period))
    x, new_cache = jax.lax.scan(group_body, x, scans)
    x = _apply_norm(cfg, params["norm_f"], x)
    unembed = params.get("unembed")
    logits = x @ (unembed if unembed is not None else params["embed"].T)
    if cfg.final_softcap > 0:
        logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, list(new_cache)
