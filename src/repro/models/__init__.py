from .registry import get_model, list_archs

__all__ = ["get_model", "list_archs"]
