"""Fault-tolerant Trainer.

Production behaviours, each unit-tested:
  * checkpoint/restart — async sharded checkpoints every ``ckpt_every``
    steps; on construction the trainer resumes from the latest checkpoint
    (params, optimizer state, step counter AND data-pipeline cursor);
  * preemption handling — SIGTERM (or ``request_stop()``) triggers a final
    synchronous checkpoint before exiting cleanly;
  * straggler detection — per-step wall times feed an EWMA z-score; steps
    slower than ``straggler_z`` sigma are logged and counted (on multi-host
    deployments this signal feeds the scheduler's replace-node policy);
  * elastic re-mesh — ``Trainer.restore_elastic(new_mesh)`` reloads the same
    checkpoint under a different device count / mesh shape and re-shards
    every leaf (the data pipeline is step-indexed so the batch stream is
    unchanged).
"""

from __future__ import annotations

import math
import os
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, latest_step, load_checkpoint
from repro.data import DataState, SyntheticLM
from repro.distributed import batch_spec, dp_size, tree_shardings
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.registry import extra_shape
from repro.optim import cosine_schedule, make_optimizer
from repro.train.step import (TrainState, auto_microbatches, build_train_step,
                              make_state)


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, workdir: str,
                 global_batch: int = 8, seq_len: int = 128,
                 lr: float = 3e-4, total_steps: int = 1000,
                 ckpt_every: int = 50, seed: int = 0,
                 optimizer: str = "adamw", straggler_z: float = 3.0,
                 use_flash: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.workdir = workdir
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.total_steps = total_steps
        self.ckpt_every = ckpt_every
        self.straggler_z = straggler_z
        self.stragglers: list = []
        self._stop = False

        self.optimizer = make_optimizer(
            optimizer, cosine_schedule(lr, min(100, total_steps // 10 + 1),
                                       total_steps))
        n_micro = auto_microbatches(cfg, global_batch, seq_len,
                                    dp_size(mesh))
        self.train_step = jax.jit(
            build_train_step(cfg, self.optimizer, n_micro=n_micro,
                             use_flash=use_flash),
            donate_argnums=(0,))

        es = extra_shape(cfg, global_batch)
        self.data = SyntheticLM(cfg.vocab, seq_len, global_batch, seed=seed,
                                extra_shape=es)

        with mesh:
            state, self.param_specs = make_state(
                jax.random.PRNGKey(seed), cfg, self.optimizer)
        self.state = jax.device_put(
            state, self._state_shardings(mesh))
        self.data_state = DataState(seed=seed, step=0)
        self.ckpt = CheckpointManager(workdir)
        self.metrics_log: list = []

        # resume if a checkpoint exists
        if latest_step(workdir) is not None:
            self.restore(mesh)

        signal.signal(signal.SIGTERM, self._on_sigterm)

    # -- sharding helpers ------------------------------------------------------
    def _state_shardings(self, mesh):
        from repro.train.step import state_specs
        specs = state_specs(self.cfg, self.optimizer, self.param_specs)
        return tree_shardings(mesh, specs)

    def _batch_shardings(self, mesh, batch):
        from jax.sharding import NamedSharding, PartitionSpec as P
        bs = batch_spec(mesh)
        out = {}
        for k, v in batch.items():
            spec = P(bs[0], *([None] * (v.ndim - 1)))
            out[k] = NamedSharding(mesh, spec)
        return out

    # -- fault-tolerance hooks -------------------------------------------------
    def _on_sigterm(self, signum, frame):
        self.request_stop()

    def request_stop(self):
        """Preemption notice: checkpoint at the next step boundary and stop."""
        self._stop = True

    def restore(self, mesh):
        self.state, aux = load_checkpoint(
            self.workdir, self.state, shardings=self._state_shardings(mesh))
        self.data_state = DataState.from_dict(aux["data"])

    def restore_elastic(self, new_mesh):
        """Elastic re-mesh: resume the run on a different mesh."""
        self.mesh = new_mesh
        n_micro = auto_microbatches(self.cfg, self.global_batch, self.seq_len,
                                    dp_size(new_mesh))
        self.train_step = jax.jit(
            build_train_step(self.cfg, self.optimizer, n_micro=n_micro,
                             use_flash=False), donate_argnums=(0,))
        self.restore(new_mesh)

    # -- main loop ---------------------------------------------------------------
    def run(self, n_steps: Optional[int] = None,
            log_every: int = 10) -> Dict[str, Any]:
        n_steps = n_steps if n_steps is not None else self.total_steps
        times = []
        ew_mean, ew_var = None, 0.0
        start_step = self.data_state.step
        with self.mesh:
            for step in range(start_step, min(start_step + n_steps,
                                              self.total_steps)):
                if self._stop:
                    break
                batch_np = self.data.batch_at(step)
                batch = jax.device_put(
                    batch_np, self._batch_shardings(self.mesh, batch_np))
                t0 = time.time()
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                times.append(dt)

                # straggler detection (EWMA z-score over step times); the
                # first few steps carry the jit-compile transient and are
                # excluded from the statistics
                if len(times) <= 3:
                    pass
                elif ew_mean is None:
                    ew_mean = dt
                else:
                    if ew_var > 0:
                        z = (dt - ew_mean) / math.sqrt(ew_var)
                        if z > self.straggler_z and len(times) > 5:
                            self.stragglers.append((step, dt, z))
                    ew_mean = 0.9 * ew_mean + 0.1 * dt
                    ew_var = 0.9 * ew_var + 0.1 * (dt - ew_mean) ** 2
                self.data_state = DataState(self.data_state.seed, step + 1)

                if step % log_every == 0 or step == self.total_steps - 1:
                    self.metrics_log.append(
                        {"step": step, "loss": float(metrics["loss"]),
                         "grad_norm": float(metrics["grad_norm"]),
                         "dt": dt})
                if (step + 1) % self.ckpt_every == 0:
                    self.ckpt.save_async(step + 1, self.state,
                                         aux={"data":
                                              self.data_state.to_dict()})
        if self._stop:
            # preemption: final synchronous checkpoint
            self.ckpt.wait()
            from repro.ckpt import save_checkpoint
            save_checkpoint(self.workdir, self.data_state.step, self.state,
                            aux={"data": self.data_state.to_dict()})
        self.ckpt.wait()
        return {"metrics": self.metrics_log, "stragglers": self.stragglers,
                "final_step": self.data_state.step}
