"""train_step builder: grad accumulation (scan over microbatches), global-norm
clipping, optimizer update.  Everything is a pure function of (state, batch),
jit/pjit-friendly; sharding comes from in_shardings/out_shardings at the
launcher level.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import clip_by_global_norm, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def auto_microbatches(cfg: ModelConfig, global_batch: int, seq: int,
                      dp: int) -> int:
    """Pick a microbatch count: bound per-microbatch tokens to ~128k while
    keeping micro_batch divisible by dp."""
    if cfg.microbatch:
        return cfg.microbatch
    target_tokens = 131072
    n = max(1, (global_batch * seq) // target_tokens)
    # n must divide global_batch and keep global_batch//n divisible by dp
    while n > 1 and (global_batch % n or (global_batch // n) % dp):
        n -= 1
    return max(1, n)


def make_state(key, cfg: ModelConfig, optimizer):
    params, specs = T.init(key, cfg)
    opt_state = optimizer.init(params)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32)), specs


def state_specs(cfg: ModelConfig, optimizer, param_specs):
    from jax.sharding import PartitionSpec as P
    return TrainState(param_specs, optimizer.state_specs(param_specs), P())


def build_train_step(cfg: ModelConfig, optimizer, n_micro: int = 1,
                     max_grad_norm: float = 1.0,
                     use_flash: bool = True) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens": (B, S), "labels": (B, S), ["extra": (B, ...)]}
    Gradients accumulate over ``n_micro`` scan steps (compute/comm overlap:
    the FSDP all-gathers of microbatch i+1 overlap the backward of i under
    XLA's latency-hiding scheduler).
    """
    loss = partial(T.loss_fn, cfg=cfg, use_flash=use_flash)

    def split(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    def train_step(state: TrainState, batch: Dict[str, Any]):
        params = state.params

        def micro_loss(p, mb):
            return loss(p, batch=mb)

        grad_fn = jax.value_and_grad(micro_loss)

        if n_micro == 1:
            l, grads = grad_fn(params, batch)
        else:
            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                acc, ltot = carry
                l, g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, ltot + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            l = lsum / n_micro

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = optimizer.update(grads, state.opt_state, params)
        metrics = {"loss": l.astype(jnp.float32), "grad_norm": gnorm,
                   "step": state.step + 1}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
