from .step import TrainState, build_train_step, auto_microbatches

__all__ = ["TrainState", "build_train_step", "auto_microbatches"]
